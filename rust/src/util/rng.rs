//! Deterministic PRNG substrate (no `rand` in the vendored set).
//!
//! [`Pcg64`] — PCG-XSH-RR 64/32 with a SplitMix64-seeded state; fast,
//! reproducible, and stream-splittable (every consumer of randomness in
//! the coordinator derives its own stream so run results are independent
//! of scheduling order).

/// PCG-XSH-RR 64/32: 64-bit state, 32-bit output.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u64,
    inc: u64,
    /// Cached second normal from Box–Muller.
    gauss_spare: Option<f64>,
}

const PCG_MULT: u64 = 6364136223846793005;

/// SplitMix64 — used for seeding and stream derivation.
fn splitmix64(x: &mut u64) -> u64 {
    *x = x.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Pcg64 {
    /// Create a generator from a seed and a stream id.  Different streams
    /// from the same seed are statistically independent.
    pub fn new(seed: u64, stream: u64) -> Pcg64 {
        let mut sm = seed;
        let state = splitmix64(&mut sm);
        let mut sm2 = stream.wrapping_mul(0xDA3E39CB94B95BDB) ^ seed;
        let inc = splitmix64(&mut sm2) | 1; // must be odd
        let mut rng = Pcg64 {
            state,
            inc,
            gauss_spare: None,
        };
        rng.next_u32(); // burn-in so state decorrelates from the seed
        rng
    }

    /// Derive a child stream (for per-worker / per-layer reproducibility).
    pub fn split(&mut self, label: u64) -> Pcg64 {
        Pcg64::new(self.next_u64(), label)
    }

    /// Raw generator state `(state, inc, cached Box–Muller spare)` — for
    /// checkpointing.  Restoring via [`Self::from_raw_state`] resumes the
    /// exact sample stream.
    pub fn raw_state(&self) -> (u64, u64, Option<f64>) {
        (self.state, self.inc, self.gauss_spare)
    }

    /// Rebuild a generator from [`Self::raw_state`] output (no burn-in —
    /// this is a resume, not a fresh seed).
    pub fn from_raw_state(state: u64, inc: u64, gauss_spare: Option<f64>) -> Pcg64 {
        Pcg64 {
            state,
            inc: inc | 1, // the increment must be odd for full period
            gauss_spare,
        }
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, bound) without modulo bias (Lemire).
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0);
        loop {
            let x = self.next_u64();
            let (hi, lo) = mul128(x, bound);
            if lo >= bound || lo >= x.wrapping_neg() % bound {
                return hi;
            }
        }
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(spare) = self.gauss_spare.take() {
            return spare;
        }
        loop {
            let u = self.uniform();
            if u <= f64::MIN_POSITIVE {
                continue;
            }
            let v = self.uniform();
            let r = (-2.0 * u.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * v;
            self.gauss_spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Fill a slice with N(0, sigma²) f32 samples.
    pub fn fill_gaussian(&mut self, out: &mut [f32], sigma: f32) {
        for x in out.iter_mut() {
            *x = (self.gaussian() as f32) * sigma;
        }
    }

    /// Zipf(s) sample over [0, n): rank-frequency token distribution used
    /// by the synthetic corpus (rejection-inversion, Hörmann & Derflinger).
    pub fn zipf(&mut self, n: u64, s: f64) -> u64 {
        debug_assert!(n >= 1);
        // Simple inversion on the harmonic CDF; fine for n ≤ ~1e5.
        let h = |x: f64| -> f64 {
            if (s - 1.0).abs() < 1e-9 {
                (x + 1.0).ln()
            } else {
                ((x + 1.0).powf(1.0 - s) - 1.0) / (1.0 - s)
            }
        };
        let h_n = h(n as f64);
        let u = self.uniform() * h_n;
        let x = if (s - 1.0).abs() < 1e-9 {
            u.exp() - 1.0
        } else {
            ((1.0 - s) * u + 1.0).powf(1.0 / (1.0 - s)) - 1.0
        };
        (x.floor() as u64).min(n - 1)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            items.swap(i, j);
        }
    }
}

#[inline]
fn mul128(a: u64, b: u64) -> (u64, u64) {
    let wide = (a as u128) * (b as u128);
    ((wide >> 64) as u64, wide as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg64::new(42, 0);
        let mut b = Pcg64::new(42, 0);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg64::new(42, 0);
        let mut b = Pcg64::new(42, 1);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut rng = Pcg64::new(7, 0);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.02);
    }

    #[test]
    fn below_is_unbiased_ish() {
        let mut rng = Pcg64::new(9, 3);
        let mut counts = [0u32; 5];
        for _ in 0..50_000 {
            counts[rng.below(5) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = Pcg64::new(11, 0);
        let n = 50_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let g = rng.gaussian();
            sum += g;
            sq += g * g;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn zipf_is_monotone_decreasing() {
        let mut rng = Pcg64::new(13, 0);
        let mut counts = vec![0u32; 16];
        for _ in 0..100_000 {
            counts[rng.zipf(16, 1.2) as usize] += 1;
        }
        assert!(counts[0] > counts[3]);
        assert!(counts[3] > counts[10]);
        assert!(counts.iter().all(|&c| c > 0));
    }

    #[test]
    fn shuffle_permutes() {
        let mut rng = Pcg64::new(17, 0);
        let mut v: Vec<u32> = (0..64).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..64).collect::<Vec<_>>());
        assert_ne!(v, (0..64).collect::<Vec<_>>());
    }

    #[test]
    fn raw_state_roundtrip_resumes_stream() {
        let mut a = Pcg64::new(3, 7);
        a.gaussian(); // populate the Box–Muller spare
        let (s, i, g) = a.raw_state();
        let mut b = Pcg64::from_raw_state(s, i, g);
        for _ in 0..16 {
            assert_eq!(a.gaussian(), b.gaussian());
        }
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn split_decorrelates() {
        let mut parent = Pcg64::new(1, 0);
        let mut c1 = parent.split(0);
        let mut c2 = parent.split(1);
        let same = (0..64).filter(|_| c1.next_u32() == c2.next_u32()).count();
        assert!(same < 4);
    }
}
