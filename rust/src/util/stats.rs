//! Error metrics and summary statistics (paper Tables 1–2, Figures 5–6,
//! and the §4.2 RMS probes) plus the timing summaries used by `bench`.

/// Cosine similarity of two flattened tensors.
///
/// Zero-norm contract (degenerate comparisons must *signal*, not hide —
/// an all-zero reference previously clamped the denominator and returned
/// a misleading 0.0):
/// * both vectors all-zero → `1.0` (they are identical);
/// * exactly one all-zero  → `NaN` (direction undefined — check with
///   `is_nan()` rather than comparing against a threshold).
pub fn cossim(x: &[f32], y: &[f32]) -> f64 {
    assert_eq!(x.len(), y.len());
    let (mut dot, mut nx, mut ny) = (0f64, 0f64, 0f64);
    for (&a, &b) in x.iter().zip(y) {
        dot += a as f64 * b as f64;
        nx += a as f64 * a as f64;
        ny += b as f64 * b as f64;
    }
    match (nx == 0.0, ny == 0.0) {
        (true, true) => 1.0,
        (true, false) | (false, true) => f64::NAN,
        (false, false) => dot / (nx.sqrt() * ny.sqrt()),
    }
}

/// Relative ℓ2 error ‖x − y‖ / ‖y‖ (y is the full-precision reference).
///
/// Zero-norm contract: with an all-zero reference the ratio is undefined,
/// so the result is `0.0` when x is also all-zero (no error) and `+∞`
/// otherwise (any deviation from a zero reference is infinitely large in
/// relative terms) — never a silently-clamped finite value.
pub fn rel_l2(x: &[f32], y: &[f32]) -> f64 {
    assert_eq!(x.len(), y.len());
    let (mut num, mut den) = (0f64, 0f64);
    for (&a, &b) in x.iter().zip(y) {
        let d = a as f64 - b as f64;
        num += d * d;
        den += b as f64 * b as f64;
    }
    if den == 0.0 {
        return if num == 0.0 { 0.0 } else { f64::INFINITY };
    }
    (num / den).sqrt()
}

/// NaN-propagating max: a NaN operand poisons the result, where
/// `f64::max` would silently discard it.  This is the one fold the
/// divergence-telemetry chain (DESIGN.md §10) is allowed to use —
/// `Tensor::max_abs` / `kernels::max_abs_logit` implement the same
/// contract with early-exit scanning loops on their f32 hot paths.
pub fn nan_max(a: f64, b: f64) -> f64 {
    if a.is_nan() || b.is_nan() {
        f64::NAN
    } else {
        a.max(b)
    }
}

/// Root mean square.
pub fn rms(x: &[f32]) -> f64 {
    if x.is_empty() {
        return 0.0;
    }
    (x.iter().map(|&a| a as f64 * a as f64).sum::<f64>() / x.len() as f64).sqrt()
}

pub fn mean(x: &[f64]) -> f64 {
    if x.is_empty() {
        return 0.0;
    }
    x.iter().sum::<f64>() / x.len() as f64
}

pub fn stddev(x: &[f64]) -> f64 {
    if x.len() < 2 {
        return 0.0;
    }
    let m = mean(x);
    (x.iter().map(|&v| (v - m) * (v - m)).sum::<f64>() / (x.len() - 1) as f64).sqrt()
}

/// p-th percentile (0–100) by linear interpolation on a sorted copy.
pub fn percentile(x: &[f64], p: f64) -> f64 {
    assert!(!x.is_empty());
    let mut sorted = x.to_vec();
    sorted.sort_by(|a, b| a.total_cmp(b));
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = rank - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Exponential moving average — the trainer's smoothed-loss display.
#[derive(Debug, Clone)]
pub struct Ema {
    alpha: f64,
    value: Option<f64>,
}

impl Ema {
    pub fn new(alpha: f64) -> Ema {
        assert!((0.0..=1.0).contains(&alpha));
        Ema { alpha, value: None }
    }

    pub fn update(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => prev + self.alpha * (x - prev),
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

/// Online mean/min/max/count accumulator (telemetry gauges).
#[derive(Debug, Clone, Default)]
pub struct Summary {
    pub count: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    pub fn observe(&mut self, x: f64) {
        if self.count == 0 {
            self.min = x;
            self.max = x;
        } else {
            self.min = self.min.min(x);
            self.max = self.max.max(x);
        }
        self.count += 1;
        self.sum += x;
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cossim_identical_is_one() {
        let x = vec![1.0, -2.0, 3.0];
        assert!((cossim(&x, &x) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn nan_max_poisons_on_either_side() {
        assert_eq!(nan_max(1.0, 2.0), 2.0);
        assert_eq!(nan_max(2.0, 1.0), 2.0);
        assert!(nan_max(f64::NAN, 1.0).is_nan());
        assert!(nan_max(1.0, f64::NAN).is_nan());
        assert_eq!(nan_max(f64::NEG_INFINITY, 3.0), 3.0);
        assert_eq!(nan_max(f64::INFINITY, 3.0), f64::INFINITY);
    }

    #[test]
    fn cossim_orthogonal_is_zero() {
        assert!(cossim(&[1.0, 0.0], &[0.0, 1.0]).abs() < 1e-12);
    }

    #[test]
    fn cossim_opposite_is_minus_one() {
        let x = vec![1.0, 2.0];
        let y = vec![-1.0, -2.0];
        assert!((cossim(&x, &y) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn rel_l2_basics() {
        assert_eq!(rel_l2(&[1.0, 1.0], &[1.0, 1.0]), 0.0);
        let e = rel_l2(&[1.1, 0.9], &[1.0, 1.0]);
        assert!((e - 0.1).abs() < 1e-6);
    }

    #[test]
    fn cossim_zero_norms_signal_degeneracy() {
        // Both zero: identical vectors.
        assert_eq!(cossim(&[0.0, 0.0], &[0.0, 0.0]), 1.0);
        // One zero: undefined direction, NOT a misleading 0.0.
        assert!(cossim(&[0.0, 0.0], &[1.0, 2.0]).is_nan());
        assert!(cossim(&[1.0, 2.0], &[0.0, 0.0]).is_nan());
        // Empty slices count as all-zero.
        assert_eq!(cossim(&[], &[]), 1.0);
    }

    #[test]
    fn rel_l2_zero_reference_signals_degeneracy() {
        // Zero reference + zero candidate: no error.
        assert_eq!(rel_l2(&[0.0, 0.0], &[0.0, 0.0]), 0.0);
        // Zero reference + any deviation: infinite relative error.
        assert_eq!(rel_l2(&[1e-6, 0.0], &[0.0, 0.0]), f64::INFINITY);
        // Tiny-but-nonzero references still behave normally.
        let r = rel_l2(&[2e-20], &[1e-20]);
        assert!((r - 1.0).abs() < 1e-9, "{r}");
    }

    #[test]
    fn rms_known_value() {
        assert!((rms(&[3.0, 4.0]) - (12.5f64).sqrt()).abs() < 1e-9);
        assert_eq!(rms(&[]), 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-9);
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.5);
        for _ in 0..32 {
            e.update(10.0);
        }
        assert!((e.get().unwrap() - 10.0).abs() < 1e-3);
    }

    #[test]
    fn summary_tracks_extremes() {
        let mut s = Summary::default();
        for x in [3.0, -1.0, 7.0] {
            s.observe(x);
        }
        assert_eq!(s.min, -1.0);
        assert_eq!(s.max, 7.0);
        assert_eq!(s.count, 3);
        assert!((s.mean() - 3.0).abs() < 1e-12);
    }
}
