//! Tier-1 gate for the self-hosting invariant analyzer (DESIGN.md §13).
//!
//! `repo_is_clean_at_head` is the contract: every PR runs the five lints
//! over the real tree via `cargo test`, so a determinism regression, a
//! hot-loop allocation, a new panic site, an unaudited `unsafe`, or a
//! schema drift fails CI without anyone remembering to run `sagebwd
//! analyze`.  The fixture tests under `rust/tests/data/lint_fixtures/`
//! prove each lint actually fires, each `sagebwd-allow` suppression
//! works, and the A3 baseline ratchets in one direction only.
//! `python/compile/check_analyzer.py --fixtures` checks the same
//! expectations without a Rust toolchain.

use std::path::{Path, PathBuf};

use sagebwd::analysis::{analyze, AnalyzeOptions, Baseline, Report};

fn repo_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

fn fixture(name: &str) -> PathBuf {
    repo_root().join("rust/tests/data/lint_fixtures").join(name)
}

/// Read-only run: never rewrites any baseline from a test.
fn run(root: &Path) -> Report {
    analyze(
        root,
        &AnalyzeOptions {
            update_baseline: false,
        },
    )
    .expect("analysis run is I/O-infallible over a checked-out tree")
}

fn render(report: &Report) -> String {
    report
        .violations
        .iter()
        .map(|v| v.to_string())
        .collect::<Vec<_>>()
        .join("\n")
}

#[test]
fn repo_is_clean_at_head() {
    let report = run(&repo_root());
    assert!(
        report.violations.is_empty(),
        "the tree must be lint-clean (A1/A2/A4/A5 everywhere, A3 at or \
         below analysis/baseline.json):\n{}",
        render(&report)
    );
    assert!(report.a3_total <= report.a3_baseline_total);
    assert!(
        !report.baseline_tightened,
        "A3 counts dropped below the committed baseline — run \
         `cargo run -- analyze` and commit the tightened baseline.json"
    );
    // The self-hosting sanity floor: the analyzer scanned its own
    // sources plus the rest of the tree, not an empty directory.
    assert!(report.files_scanned > 40, "only {} files scanned", report.files_scanned);
}

#[test]
fn seeded_fixture_fires_every_lint() {
    let report = run(&fixture("seeded"));
    let got: Vec<(String, usize, String)> = report
        .violations
        .iter()
        .map(|v| (v.file.clone(), v.line, v.lint.to_string()))
        .collect();
    // Kept in lockstep with check_analyzer.py --fixtures.
    let expect: Vec<(String, usize, String)> = [
        ("rust/src/bench.rs", 1, "A5"),  // isa no longer emitted
        ("rust/src/bench.rs", 1, "A5"),  // ns_per_iter no longer emitted
        ("rust/src/bench.rs", 29, "A5"), // isa_tier not in the schema
        ("rust/src/bench.rs", 30, "A5"), // ns_per_op not in the schema
        ("rust/src/kernels/attention.rs", 3, "A1"), // HashMap
        ("rust/src/kernels/attention.rs", 8, "A2"), // to_vec in hot loop
        ("rust/src/main.rs", 4, "A3"),   // 3 sites over a 0 baseline
        ("rust/src/runtime/raw.rs", 4, "A4"), // bare unsafe
        ("rust/src/runtime/raw.rs", 13, "A0"), // allow without a reason
        ("rust/src/runtime/raw.rs", 14, "A4"), // reason-less allow is void
        ("rust/src/telemetry/trace.rs", 1, "A5"), // p99_ns no longer emitted
        ("rust/src/telemetry/trace.rs", 29, "A5"), // p99 not in the schema
        ("rust/src/tensor/linalg.rs", 1, "A2"), // manifest entry matches no fn
        ("rust/src/tensor/timing.rs", 4, "A1"), // Instant
    ]
    .iter()
    .map(|(f, l, id)| (f.to_string(), *l, id.to_string()))
    .collect();
    assert_eq!(got, expect, "full report:\n{}", render(&report));
    // The prologue `vec![...]` in the hot fn and the `#[cfg(test)]`
    // Instant were NOT flagged — that is the loop-body / test-region
    // scoping working, and the assert_eq above already proves it.
    assert_eq!(report.a3_total, 3);
}

#[test]
fn suppressed_fixture_is_quiet() {
    let report = run(&fixture("suppressed"));
    assert!(
        report.violations.is_empty(),
        "every sagebwd-allow(...) with a reason must suppress its site:\n{}",
        render(&report)
    );
    assert_eq!(
        report.a3_total, 0,
        "allowed A3 sites must not count toward the ratchet"
    );
}

#[test]
fn clean_fixture_passes() {
    let report = run(&fixture("clean"));
    assert!(report.violations.is_empty(), "{}", render(&report));
    assert!(!report.baseline_tightened);
}

#[test]
fn ratchet_increase_fails_and_decrease_tightens() {
    let dir = std::env::temp_dir().join(format!(
        "sagebwd_ratchet_{}_{:?}",
        std::process::id(),
        std::thread::current().id()
    ));
    let src = dir.join("rust/src");
    std::fs::create_dir_all(src.join("analysis")).unwrap();
    let bpath = src.join("analysis/baseline.json");
    let one_site = "pub fn f(x: Option<u32>) -> u32 { x.unwrap() }\n";
    std::fs::write(src.join("lib.rs"), one_site).unwrap();

    // No baseline at all: that is itself a violation (plus the count).
    let report = run(&dir);
    assert_eq!(report.violations.len(), 2, "{}", render(&report));
    assert!(report.violations.iter().all(|v| v.lint == "A3"));

    // Bootstrap via write_baseline, then the tree is clean.
    sagebwd::analysis::write_baseline(&dir).unwrap();
    assert_eq!(Baseline::load(&bpath).unwrap().unwrap().total, 1);
    assert!(run(&dir).violations.is_empty());

    // Counts below the baseline: no violation, and an updating run
    // rewrites the baseline downward.
    std::fs::write(
        &bpath,
        r#"{"files":{"rust/src/lib.rs":3},"schema":"sagebwd-analysis-baseline-v1","total":3}"#,
    )
    .unwrap();
    let tightened = analyze(
        &dir,
        &AnalyzeOptions {
            update_baseline: true,
        },
    )
    .unwrap();
    assert!(tightened.violations.is_empty());
    assert!(tightened.baseline_tightened && tightened.baseline_updated);
    assert_eq!(Baseline::load(&bpath).unwrap().unwrap().total, 1);

    // Nothing further to tighten on the next run.
    let again = analyze(
        &dir,
        &AnalyzeOptions {
            update_baseline: true,
        },
    )
    .unwrap();
    assert!(!again.baseline_tightened && !again.baseline_updated);

    // A second site appears: count 2 > baseline 1 fails, points at the
    // first site past the allowance, and never rewrites the baseline.
    std::fs::write(
        src.join("lib.rs"),
        "pub fn f(x: Option<u32>) -> u32 { x.unwrap() }\n\
         pub fn g(x: Option<u32>) -> u32 { x.unwrap() }\n",
    )
    .unwrap();
    let grown = analyze(
        &dir,
        &AnalyzeOptions {
            update_baseline: true,
        },
    )
    .unwrap();
    assert_eq!(grown.violations.len(), 1, "{}", render(&grown));
    assert_eq!(grown.violations[0].lint, "A3");
    assert_eq!(grown.violations[0].line, 2);
    assert!(!grown.baseline_updated);
    assert_eq!(
        Baseline::load(&bpath).unwrap().unwrap().total,
        1,
        "a failing run must never raise the baseline"
    );

    std::fs::remove_dir_all(&dir).ok();
}
