//! Clean fixture: nothing for any lint to object to.

use std::collections::BTreeMap;

pub fn histogram(xs: &[u32]) -> BTreeMap<u32, usize> {
    let mut out = BTreeMap::new();
    for &x in xs {
        *out.entry(x).or_insert(0) += 1;
    }
    out
}
