//! Seeded A5 fixture: drifted bench row schema (`isa_tier` replaced
//! the documented `isa`, `ns_per_op` the documented `ns_per_iter`).

use crate::util::json::Json;

pub const BENCH_SCHEMA: &str = "sagebwd-bench-v1";

pub fn envelope(bench: &str) -> Json {
    Json::from_pairs(vec![
        ("schema", Json::from(BENCH_SCHEMA)),
        ("bench", Json::from(bench)),
        ("runs", Json::Arr(Vec::new())),
    ])
}

pub fn run_to_json(threads_default: usize, rows: Vec<Json>) -> Json {
    Json::from_pairs(vec![
        ("threads_default", Json::from(threads_default)),
        ("rows", Json::Arr(rows)),
    ])
}

pub fn row_to_json(op: &str, shape: &str, variant: &str, threads: usize, isa: &str, ns: f64) -> Json {
    Json::from_pairs(vec![
        ("op", Json::from(op)),
        ("shape", Json::from(shape)),
        ("variant", Json::from(variant)),
        ("threads", Json::from(threads)),
        ("isa_tier", Json::from(isa)),
        ("ns_per_op", Json::from(ns)),
        ("tokens_per_s", Json::Null),
    ])
}
