//! Seeded A1+A2 fixture: nondeterministic container + hot-loop alloc.

use std::collections::HashMap;

pub fn demo_fwd_ws(n: usize, out: &mut [f32]) {
    let scratch = vec![0f32; n]; // prologue allocation: legal
    for i in 0..n {
        let t = scratch.to_vec();
        out[i] = t[i];
    }
}
