//! Seeded A3 fixture: three panic-family sites over a zero baseline.

pub fn read_config(path: &str) -> usize {
    let text = std::fs::read_to_string(path).unwrap();
    let n: usize = text.trim().parse().expect("bad number");
    if n == 0 {
        panic!("zero config");
    }
    n
}
