//! Seeded A4 fixture: unsafe audit.

pub fn cast_a(x: &[f32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(x.as_ptr() as *const u8, x.len() * 4) }
}

pub fn cast_b(x: &[f32]) -> &[u8] {
    // SAFETY: x is a live &[f32]; len*4 bytes are valid and u8 alignment is 1.
    unsafe { std::slice::from_raw_parts(x.as_ptr() as *const u8, x.len() * 4) }
}

pub fn cast_c(x: &[f32]) -> &[u8] {
    // sagebwd-allow(A4)
    unsafe { std::slice::from_raw_parts(x.as_ptr() as *const u8, x.len() * 4) }
}
