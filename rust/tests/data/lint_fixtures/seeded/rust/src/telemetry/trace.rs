//! Seeded A5 fixture: drifted trace span schema (`p99` replaced the
//! documented `p99_ns`).

use crate::util::json::Json;

pub const TRACE_SCHEMA: &str = "sagebwd-trace-v1";

pub fn meta_to_json(threads: usize, spans: usize, counters: usize) -> Json {
    Json::from_pairs(vec![
        ("schema", Json::from(TRACE_SCHEMA)),
        ("kind", Json::from("meta")),
        ("threads", Json::from(threads)),
        ("spans", Json::from(spans)),
        ("counters", Json::from(counters)),
    ])
}

pub fn span_to_json(name: &str, calls: i64, total: i64) -> Json {
    Json::from_pairs(vec![
        ("kind", Json::from("span")),
        ("name", Json::from(name)),
        ("parent", Json::Null),
        ("calls", Json::from(calls)),
        ("total_ns", Json::from(total)),
        ("self_ns", Json::from(total)),
        ("min_ns", Json::from(total)),
        ("max_ns", Json::from(total)),
        ("p50_ns", Json::from(total)),
        ("p99", Json::from(total)),
    ])
}

pub fn counter_to_json(name: &str, value: i64) -> Json {
    Json::from_pairs(vec![
        ("kind", Json::from("counter")),
        ("name", Json::from(name)),
        ("value", Json::from(value)),
    ])
}
