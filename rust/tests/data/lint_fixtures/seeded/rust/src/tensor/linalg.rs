//! Seeded A2 manifest-drift fixture: `pack_transpose` is gone.

pub fn gemm_nn_rows() {}
pub fn i8_gemm_nn_rows() {}
pub fn par_gemm_nn() {}
pub fn int8_gemm_nn() {}
pub fn int8_gemm_nt() {}
pub fn int8_gemm_tn() {}
