//! Seeded A1 fixture: wall-clock read in a numeric module.

pub fn tick() -> u64 {
    let t0 = std::time::Instant::now();
    t0.elapsed().as_nanos() as u64
}

#[cfg(test)]
mod tests {
    #[test]
    fn clock_in_test_region_is_fine() {
        let _ = std::time::Instant::now();
    }
}
