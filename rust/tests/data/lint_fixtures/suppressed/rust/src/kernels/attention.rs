//! Suppressed twin of the seeded A1+A2 fixture.

// sagebwd-allow(A1): fixture — exercising suppression
use std::collections::HashMap;

pub fn demo_fwd_ws(n: usize, out: &mut [f32]) {
    let scratch = vec![0f32; n];
    for i in 0..n {
        // sagebwd-allow(A2): fixture — exercising suppression
        let t = scratch.to_vec();
        out[i] = t[i];
    }
}
