//! Suppressed A3 fixture: allowed sites never count toward the ratchet.

pub fn read_config(path: &str) -> usize {
    let text = std::fs::read_to_string(path).unwrap(); // sagebwd-allow(A3): fixture
    let n: usize = text.trim().parse().expect("bad"); // sagebwd-allow(A3): fixture
    n
}
