//! Suppressed A4 fixture.

pub fn cast_a(x: &[f32]) -> &[u8] {
    // sagebwd-allow(A4): fixture — audited by hand
    unsafe { std::slice::from_raw_parts(x.as_ptr() as *const u8, x.len() * 4) }
}
