//! Suppressed A1 fixture.

pub fn tick() -> u64 {
    // sagebwd-allow(A1): fixture — harness-layer timer
    let t0 = std::time::Instant::now();
    t0.elapsed().as_nanos() as u64
}
