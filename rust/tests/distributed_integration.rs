//! Data-parallel runtime integration: DistTrainer must (a) train, (b) be
//! deterministic for a fixed worker count, (c) match the microbatch math.

use sagebwd::config::TrainConfig;
use sagebwd::coordinator::distributed::DistTrainer;
use sagebwd::telemetry::Log;

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("grad_step_sage_qknorm.manifest.json").exists() {
        eprintln!("SKIP: artifacts/ not built");
        return None;
    }
    Some(dir)
}

fn cfg(steps: u64, tps: u64) -> TrainConfig {
    TrainConfig {
        variant: "sage_qknorm".into(),
        steps,
        tokens_per_step: tps,
        warmup_steps: 1,
        peak_lr: 3e-3,
        min_lr_frac: 0.1,
        seed: 0,
        checkpoint_every: 0,
        log_every: 0,
        clip_norm: 0.0,
        grad_noise_sigma: 0.0,
        ..TrainConfig::default()
    }
}

#[test]
fn two_workers_train_and_loss_drops() {
    let Some(dir) = artifacts() else { return };
    let mut t = DistTrainer::new(dir, cfg(3, 1024), 2).unwrap();
    assert_eq!(t.num_workers(), 2);
    let first = t.train_step().unwrap();
    t.train_step().unwrap();
    let last = t.train_step().unwrap();
    assert!(last < first, "{last} !< {first}");
}

#[test]
fn deterministic_for_fixed_worker_count() {
    let Some(dir) = artifacts() else { return };
    let run = |dir: std::path::PathBuf| {
        let mut t = DistTrainer::new(dir, cfg(2, 1024), 2).unwrap();
        t.run(&Log::new(false)).unwrap()
    };
    let a = run(dir.clone());
    let b = run(dir);
    assert_eq!(a, b);
}

#[test]
fn uneven_microbatch_split_works() {
    let Some(dir) = artifacts() else { return };
    // 1024 tokens = 4 microbatches over 3 workers → 2/1/1 split.
    let mut t = DistTrainer::new(dir, cfg(2, 1024), 3).unwrap();
    let loss = t.train_step().unwrap();
    assert!(loss.is_finite());
}
