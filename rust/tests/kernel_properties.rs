//! Property tests over the native kernels (no artifacts needed):
//! INT8-vs-f32 error bounds across σ, the K-smoothing win on outlier-heavy
//! K, quantizer edge cases, and native-backend ABI equivalence.

use sagebwd::experiments::common::gaussian_qkvdo;
use sagebwd::kernels::{self, quant, AttnConfig};
use sagebwd::runtime::{AttentionBackend, NativeBackend, Value};
use sagebwd::tensor::Tensor;
use sagebwd::util::rng::Pcg64;
use sagebwd::util::stats::{cossim, rel_l2};

fn cfg16() -> AttnConfig {
    AttnConfig {
        block_q: 16,
        block_kv: 16,
        ..Default::default()
    }
}

#[test]
fn int8_error_grows_with_sigma_but_stays_bounded() {
    // The Table-1 shape: quantization error grows with σ_QK, yet inside
    // the trained regime (σ ≤ 4, QK-norm keeps you there) the INT8 path
    // stays within the documented bounds of exact attention.
    let mut prev_dq_rel = 0.0;
    for (sigma, max_o_rel, max_dq_rel) in [(1.0f32, 0.03, 0.10), (2.0, 0.06, 0.20), (4.0, 0.12, 0.35)] {
        let mut worst_o: f64 = 0.0;
        let mut worst_dq: f64 = 0.0;
        let mut mean_dq = 0.0;
        for seed in 0..3u64 {
            let [q, k, v, do_] = gaussian_qkvdo(64, 32, sigma, sigma, 1.0, 1.0, 100 + seed);
            let sage = kernels::sage_bwd(&q, &k, &v, &do_, &cfg16()).unwrap();
            let fpa = kernels::fpa_bwd(&q, &k, &v, &do_, false).unwrap();
            worst_o = worst_o.max(rel_l2(&sage.o.data, &fpa.o.data));
            let dq_rel = rel_l2(&sage.dq.data, &fpa.dq.data);
            worst_dq = worst_dq.max(dq_rel);
            mean_dq += dq_rel / 3.0;
            assert!(
                cossim(&sage.dq.data, &fpa.dq.data) > 0.95,
                "σ={sigma} seed={seed}: dq cossim collapsed"
            );
        }
        assert!(worst_o < max_o_rel, "σ={sigma}: o rel {worst_o} ≥ {max_o_rel}");
        assert!(worst_dq < max_dq_rel, "σ={sigma}: dq rel {worst_dq} ≥ {max_dq_rel}");
        assert!(
            mean_dq >= prev_dq_rel * 0.5,
            "error should not collapse as σ grows (σ={sigma}: {mean_dq} vs {prev_dq_rel})"
        );
        prev_dq_rel = mean_dq;
    }
}

/// Plant large shared offsets on a few channels of K — the outlier pattern
/// §3 says K-smoothing exists for.
fn add_channel_outliers(k: &mut Tensor, sigma: f32, seed: u64) {
    let d = k.shape[1];
    let mut rng = Pcg64::new(seed, 0xB1A5);
    let biases: Vec<f32> = (0..d)
        .map(|_| {
            if rng.uniform() < 0.2 {
                8.0 * sigma * if rng.next_u32() & 1 == 1 { 1.0 } else { -1.0 }
            } else {
                0.0
            }
        })
        .collect();
    for row in k.data.chunks_exact_mut(d) {
        for (x, b) in row.iter_mut().zip(&biases) {
            *x += b;
        }
    }
}

#[test]
fn k_smoothing_strictly_reduces_error_on_outlier_heavy_k() {
    let nosm = AttnConfig {
        k_smoothing: false,
        ..Default::default()
    };
    for seed in 0..3u64 {
        let [q, mut k, v, do_] = gaussian_qkvdo(128, 64, 2.0, 2.0, 1.0, 0.5, 700 + seed);
        add_channel_outliers(&mut k, 2.0, seed);
        let fpa = kernels::fpa_bwd(&q, &k, &v, &do_, false).unwrap();
        let ksm = kernels::pseudo_quant_trace(&q, &k, &v, &do_, &AttnConfig::default()).unwrap();
        let raw = kernels::pseudo_quant_trace(&q, &k, &v, &do_, &nosm).unwrap();
        for (name, s, r, f) in [
            ("o", &ksm.o, &raw.o, &fpa.o),
            ("dq", &ksm.dq, &raw.dq, &fpa.dq),
        ] {
            let e_sm = rel_l2(&s.data, &f.data);
            let e_raw = rel_l2(&r.data, &f.data);
            assert!(
                e_sm < e_raw,
                "seed {seed} {name}: K-smoothing did not reduce error ({e_sm} vs {e_raw})"
            );
        }
    }
}

#[test]
fn k_smoothing_also_helps_the_blocked_kernel() {
    let nosm = AttnConfig {
        block_q: 16,
        block_kv: 16,
        k_smoothing: false,
        ..Default::default()
    };
    let [q, mut k, v, do_] = gaussian_qkvdo(64, 32, 2.0, 2.0, 1.0, 0.5, 900);
    add_channel_outliers(&mut k, 2.0, 1);
    let fpa = kernels::fpa_bwd(&q, &k, &v, &do_, false).unwrap();
    let sm = kernels::sage_bwd(&q, &k, &v, &do_, &cfg16()).unwrap();
    let raw = kernels::sage_bwd(&q, &k, &v, &do_, &nosm).unwrap();
    let e_sm = rel_l2(&sm.o.data, &fpa.o.data);
    let e_raw = rel_l2(&raw.o.data, &fpa.o.data);
    assert!(e_sm < e_raw, "blocked kernel: {e_sm} vs {e_raw}");
}

#[test]
fn all_zero_inputs_are_safe() {
    // Exercises the EPS_SCALE guard end to end: δ would be 0 on every
    // tile, which must not produce NaNs anywhere.
    let z = Tensor::zeros(&[32, 16]);
    let cfg = AttnConfig {
        block_q: 16,
        block_kv: 16,
        ..Default::default()
    };
    let tr = kernels::sage_bwd(&z, &z, &z, &z, &cfg).unwrap();
    for (name, t) in [("o", &tr.o), ("dq", &tr.dq), ("dk", &tr.dk), ("dv", &tr.dv)] {
        assert!(t.is_finite(), "{name} not finite on zero inputs");
        assert!(t.max_abs() == 0.0, "{name} nonzero on zero inputs");
    }
    // And the zero-norm metrics now signal instead of lying.
    assert_eq!(rel_l2(&tr.dq.data, &tr.dq.data), 0.0);
    assert!(cossim(&tr.dq.data, &Tensor::randn(&[32, 16], 1.0, &mut Pcg64::new(1, 0)).data).is_nan());
}

#[test]
fn quantize_roundtrip_error_within_half_step_everywhere() {
    let mut rng = Pcg64::new(11, 0);
    for _ in 0..50 {
        let t = Tensor::randn(&[8, 8], 3.0, &mut rng);
        let (q, s) = quant::quantize_per_block(&t.data);
        let back = quant::dequantize(&q, s);
        for (a, b) in t.data.iter().zip(&back) {
            assert!((a - b).abs() <= 0.5 * s + 1e-6);
        }
    }
}

#[test]
fn native_backend_matches_direct_kernel_calls() {
    // The backend is a pure dispatcher: trace_pseudo through the ABI must
    // equal pseudo_quant_trace called directly.
    let mut be = NativeBackend::new();
    let qkvdo = gaussian_qkvdo(128, 64, 2.0, 2.0, 1.0, 0.5, 42);
    let inputs: Vec<Value> = qkvdo.iter().cloned().map(Value::F32).collect();
    let out = be.execute("trace_pseudo", &inputs).unwrap();
    let direct = kernels::pseudo_quant_trace(
        &qkvdo[0], &qkvdo[1], &qkvdo[2], &qkvdo[3],
        &AttnConfig::default(),
    )
    .unwrap();
    assert_eq!(out[0].as_f32().unwrap(), &direct.o);
    assert_eq!(out[1].as_f32().unwrap(), &direct.dq);
    assert_eq!(out[10].as_f32().unwrap(), &direct.ds);
}

#[test]
fn fp_ds_path_closes_part_of_the_gap() {
    // §7 extension finding: keeping dS in FP is at most a marginal win —
    // the error is inherited from the quantized forward.
    let [q, k, v, do_] = gaussian_qkvdo(128, 64, 4.0, 4.0, 1.0, 0.02, 77);
    let fpa = kernels::fpa_bwd(&q, &k, &v, &do_, false).unwrap();
    let int8 = kernels::pseudo_quant_trace(&q, &k, &v, &do_, &AttnConfig::default()).unwrap();
    let fpds = kernels::pseudo_quant_trace(
        &q, &k, &v, &do_,
        &AttnConfig { quant_ds: false, ..Default::default() },
    )
    .unwrap();
    let r_int8 = rel_l2(&int8.dq.data, &fpa.dq.data);
    let r_fpds = rel_l2(&fpds.dq.data, &fpa.dq.data);
    assert!(r_fpds <= r_int8 * 1.02, "fp-dS should not be worse: {r_fpds} vs {r_int8}");
    assert!(r_fpds > r_int8 * 0.25, "fp-dS should not magically fix the forward-inherited error");
}
