//! Property tests for the cache-blocked compute engine
//! (`rust/src/tensor/linalg.rs`): the determinism contract of DESIGN.md
//! §11 — **blocked == naive == parallel, bitwise, at any thread count** —
//! across odd/edge shapes and `SAGEBWD_THREADS ∈ {1, 4}`, plus the
//! cross-language golden GEMM vectors emitted by
//! `python -m compile.make_golden --gemm-only`.
//!
//! `SAGEBWD_THREADS` and `SAGEBWD_ISA` are process-global state: exactly
//! one test here mutates each, behind [`ENV_LOCK`], and every *other*
//! test in this binary uses the explicit `*_threads` entry points (which
//! never read the environment) and/or the thread-local [`simd::with_isa`]
//! pin (which takes precedence over the env) — so a concurrent env write
//! can never change another test's result.  Any future test that touches
//! either variable must hold the same lock.
//!
//! ISA tiers (DESIGN.md §15): forcing a tier above [`simd::hw_tier`]
//! clamps down at resolution time, so the tier-sweep tests below are safe
//! to run on any host — on a pre-AVX2 machine they degenerate to
//! scalar-vs-scalar and still exercise the pin/restore harness.

use std::path::Path;
use std::sync::Mutex;

use sagebwd::kernels::quant;
use sagebwd::tensor::simd::{self, IsaTier};
use sagebwd::tensor::{linalg, Tensor, Workspace};
use sagebwd::util::json;
use sagebwd::util::rng::Pcg64;

/// Odd/edge shapes: 1×1, degenerate k=0 reduction, primes, exact
/// register-block multiples, and non-multiple-of-block sizes.
const SHAPES: &[(usize, usize, usize)] = &[
    (1, 1, 1),
    (2, 0, 3),
    (5, 3, 7),
    (17, 13, 9),
    (33, 7, 5),
    (4, 4, 4),
    (64, 32, 48),
    (127, 63, 31),
];

fn randv(len: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg64::new(seed, 0x11A7);
    let mut v = vec![0f32; len];
    rng.fill_gaussian(&mut v, 2.0);
    v
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn all_three_layouts_bitwise_equal_naive_across_shapes_and_threads() {
    for &(m, k, n) in SHAPES {
        let a = randv(m * k, 1 + (m * 31 + k) as u64);
        let b = randv(k * n, 2 + (n * 17 + k) as u64);
        let bt = randv(n * k, 3 + (m + n) as u64); // (n, k) operand for nt
        let at = randv(k * m, 4 + (m * 7) as u64); // (k, m) operand for tn
        let mut want = vec![0f32; m * n];
        let mut got = vec![0f32; m * n];

        linalg::naive_matmul(&a, &b, m, k, n, &mut want);
        linalg::gemm_nn(&a, &b, m, k, n, &mut got);
        assert_eq!(bits(&want), bits(&got), "nn blocked ({m},{k},{n})");
        for threads in [1, 2, 4, 7] {
            got.fill(f32::NAN); // stale contents must not leak through
            linalg::matmul_threads(&a, &b, m, k, n, &mut got, threads);
            assert_eq!(bits(&want), bits(&got), "nn threads={threads} ({m},{k},{n})");
        }

        linalg::naive_matmul_nt(&a, &bt, m, k, n, &mut want);
        for threads in [1, 4] {
            got.fill(f32::NAN);
            linalg::matmul_nt_threads(&a, &bt, m, k, n, &mut got, threads);
            assert_eq!(bits(&want), bits(&got), "nt threads={threads} ({m},{k},{n})");
        }

        linalg::naive_matmul_tn(&at, &b, m, k, n, &mut want);
        for threads in [1, 4] {
            got.fill(f32::NAN);
            linalg::matmul_tn_threads(&at, &b, m, k, n, &mut got, threads);
            assert_eq!(bits(&want), bits(&got), "tn threads={threads} ({m},{k},{n})");
        }
    }
}

#[test]
fn k_zero_reduction_is_exactly_zero_not_garbage() {
    // The k=0 "empty sum" case: every layout must produce an all-zero
    // output (the naive references' defined behavior), never stale or
    // uninitialized values.
    let (m, k, n) = (3, 0, 5);
    let a: Vec<f32> = vec![];
    let b: Vec<f32> = vec![];
    let mut out = vec![7.0f32; m * n];
    linalg::gemm_nn(&a, &b, m, k, n, &mut out);
    assert!(out.iter().all(|&x| x == 0.0), "blocked k=0 must zero the output");
    out.fill(7.0);
    linalg::matmul_threads(&a, &b, m, k, n, &mut out, 4);
    assert!(out.iter().all(|&x| x == 0.0), "parallel k=0 must zero the output");
    let mut out_i = vec![9i32; m * n];
    linalg::int8_gemm_nn(&[], &[], m, k, n, &mut out_i);
    assert!(out_i.iter().all(|&x| x == 0), "i8 k=0 must zero the output");
}

#[test]
fn int8_gemm_bitwise_equal_reference_across_shapes_and_threads() {
    for &(m, k, n) in SHAPES {
        let a: Vec<i8> = (0..m * k).map(|i| (i as i32 * 37 % 255 - 127) as i8).collect();
        let b: Vec<i8> = (0..k * n).map(|i| (i as i32 * 91 % 255 - 127) as i8).collect();
        let want = quant::int8_gemm(&a, &b, m, k, n);
        let mut got = vec![0i32; m * n];
        linalg::int8_gemm_nn(&a, &b, m, k, n, &mut got);
        assert_eq!(want, got, "i8 nn ({m},{k},{n})");
        for threads in [1, 4] {
            got.fill(-1);
            linalg::int8_gemm_nn_threads(&a, &b, m, k, n, &mut got, threads);
            assert_eq!(want, got, "i8 threads={threads} ({m},{k},{n})");
        }
        // Transposed layouts against their quant references.
        let mut pack = Vec::new();
        let mut bt = vec![0i8; k * n];
        linalg::pack_transpose_i8(&b, k, n, &mut bt);
        linalg::int8_gemm_nt(&a, &bt, m, k, n, &mut got, &mut pack);
        assert_eq!(want, got, "i8 nt ({m},{k},{n})");
        let mut at = vec![0i8; m * k];
        linalg::pack_transpose_i8(&a, m, k, &mut at);
        linalg::int8_gemm_tn(&at, &b, m, k, n, &mut got, &mut pack);
        assert_eq!(want, got, "i8 tn ({m},{k},{n})");
    }
}

/// Every requestable tier, in order; requests above the hardware tier
/// clamp down inside the dispatcher, so sweeping all three is portable.
const TIERS: &[IsaTier] = &[IsaTier::Scalar, IsaTier::Avx2, IsaTier::Fma];

#[test]
fn int8_gemm_bitwise_identical_across_isa_tiers_and_threads() {
    // The INT8 contract of DESIGN.md §15: exact i32 arithmetic, hence
    // bitwise identical across *all* tiers, thread counts, and layouts.
    for &(m, k, n) in SHAPES {
        let a: Vec<i8> = (0..m * k).map(|i| (i as i32 * 53 % 255 - 127) as i8).collect();
        let b: Vec<i8> = (0..k * n).map(|i| (i as i32 * 29 % 255 - 127) as i8).collect();
        let want = quant::int8_gemm(&a, &b, m, k, n);
        let mut bt = vec![0i8; k * n];
        linalg::pack_transpose_i8(&b, k, n, &mut bt);
        let mut at = vec![0i8; m * k];
        linalg::pack_transpose_i8(&a, m, k, &mut at);
        for &tier in TIERS {
            simd::with_isa(tier, || {
                let mut got = vec![0i32; m * n];
                let mut pack = Vec::new();
                for threads in [1, 3, 4] {
                    got.fill(-1);
                    linalg::int8_gemm_nn_threads(&a, &b, m, k, n, &mut got, threads);
                    assert_eq!(want, got, "i8 nn {tier:?} t={threads} ({m},{k},{n})");
                    got.fill(-1);
                    linalg::int8_gemm_nt_threads(&a, &bt, m, k, n, &mut got, threads, &mut pack);
                    assert_eq!(want, got, "i8 nt {tier:?} t={threads} ({m},{k},{n})");
                    got.fill(-1);
                    linalg::int8_gemm_tn_threads(&at, &b, m, k, n, &mut got, threads, &mut pack);
                    assert_eq!(want, got, "i8 tn {tier:?} t={threads} ({m},{k},{n})");
                }
            });
        }
    }
}

#[test]
fn f32_tiers_thread_invariant_and_non_fma_bitwise_scalar() {
    // Per-tier: blocked == parallel bitwise at any thread count.  Across
    // tiers: Scalar and Avx2 agree bitwise (same two-rounding order per
    // accumulation step); Fma rounds once per step, so it may drift — but
    // only within a standard forward-error envelope for a k-term dot
    // product, never unboundedly.
    for &(m, k, n) in SHAPES {
        let a = randv(m * k, 70 + (m * 13 + k) as u64);
        let b = randv(k * n, 71 + (n * 5 + k) as u64);
        let mut scalar = vec![0f32; m * n];
        simd::with_isa(IsaTier::Scalar, || {
            linalg::gemm_nn(&a, &b, m, k, n, &mut scalar);
        });
        for &tier in TIERS {
            let effective = tier.min(simd::hw_tier());
            simd::with_isa(tier, || {
                let mut first: Option<Vec<u32>> = None;
                for threads in [1, 2, 4, 7] {
                    let mut got = vec![f32::NAN; m * n];
                    linalg::matmul_threads(&a, &b, m, k, n, &mut got, threads);
                    let gb = bits(&got);
                    match &first {
                        None => first = Some(gb),
                        Some(fb) => assert_eq!(
                            fb, &gb,
                            "within-tier thread invariance {tier:?} t={threads} ({m},{k},{n})"
                        ),
                    }
                }
                let got = first.unwrap();
                if effective != IsaTier::Fma {
                    assert_eq!(
                        bits(&scalar),
                        got,
                        "{tier:?} (effective {effective:?}) must match scalar bitwise ({m},{k},{n})"
                    );
                } else {
                    for (i, &gb) in got.iter().enumerate() {
                        let s = scalar[i];
                        let g = f32::from_bits(gb);
                        let tol = 1e-5 * (k.max(1) as f32) * s.abs().max(1.0);
                        assert!(
                            (s - g).abs() <= tol,
                            "fma drift out of bounds at {i}: {s} vs {g} ({m},{k},{n})"
                        );
                    }
                }
            });
        }
    }
}

/// Serializes every test that mutates `SAGEBWD_THREADS` / `SAGEBWD_ISA`
/// (see module doc).
static ENV_LOCK: Mutex<()> = Mutex::new(());

#[test]
fn sagebwd_isa_env_is_respected_clamped_and_overridden_by_pin() {
    let _guard = ENV_LOCK.lock().unwrap();
    let saved = std::env::var("SAGEBWD_ISA").ok();
    let hw = simd::hw_tier();
    let default = hw.min(IsaTier::Avx2);

    // Only numerics-preserving values (`scalar`/`avx2`/unknown) are ever
    // written here: tests in this binary run concurrently, and a brief
    // `fma` in the process env could leak into another test's unpinned
    // dispatch on FMA hardware.  Fma clamping is exercised via the
    // thread-local pin sweep instead ([`TIERS`]).
    std::env::set_var("SAGEBWD_ISA", "scalar");
    assert_eq!(simd::active_tier(), IsaTier::Scalar);
    // Parsing is case/whitespace-insensitive; requests clamp to hardware.
    std::env::set_var("SAGEBWD_ISA", "  AVX2 ");
    assert_eq!(simd::active_tier(), IsaTier::Avx2.min(hw));
    // Unknown values fall back to the default rather than guessing.
    std::env::set_var("SAGEBWD_ISA", "avx512");
    assert_eq!(simd::active_tier(), default);
    // The thread-local pin wins over the environment.
    std::env::set_var("SAGEBWD_ISA", "avx2");
    simd::with_isa(IsaTier::Scalar, || {
        assert_eq!(simd::active_tier(), IsaTier::Scalar);
    });
    assert_eq!(simd::active_tier(), IsaTier::Avx2.min(hw));

    // End to end: an env-forced scalar engine matches the default engine
    // bitwise (the default tier never exceeds Avx2, which is bitwise
    // scalar for f32 by construction — DESIGN.md §15).
    let (m, k, n) = (17, 13, 9);
    let a = randv(m * k, 95);
    let b = randv(k * n, 96);
    let mut forced = vec![0f32; m * n];
    let mut dflt = vec![0f32; m * n];
    std::env::set_var("SAGEBWD_ISA", "scalar");
    linalg::gemm_nn(&a, &b, m, k, n, &mut forced);
    std::env::remove_var("SAGEBWD_ISA");
    linalg::gemm_nn(&a, &b, m, k, n, &mut dflt);
    assert_eq!(bits(&forced), bits(&dflt));

    match saved {
        Some(v) => std::env::set_var("SAGEBWD_ISA", v),
        None => std::env::remove_var("SAGEBWD_ISA"),
    }
}

#[test]
fn sagebwd_threads_env_is_respected_and_result_invariant() {
    // The env knob CI drives (`SAGEBWD_THREADS ∈ {1, 4}`): thread_count()
    // honors it, and the auto-dispatching entry points produce bitwise
    // identical results under both settings.
    let _guard = ENV_LOCK.lock().unwrap();
    let saved = std::env::var("SAGEBWD_THREADS").ok();
    // Big enough to cross PAR_MIN_VOLUME so the auto path really fans out.
    let (m, k, n) = (256, 64, 512);
    assert!(m * k * n >= linalg::PAR_MIN_VOLUME);
    let a = randv(m * k, 90);
    let b = randv(k * n, 91);
    let mut out1 = vec![0f32; m * n];
    let mut out4 = vec![0f32; m * n];

    std::env::set_var("SAGEBWD_THREADS", "1");
    assert_eq!(linalg::thread_count(), 1);
    linalg::matmul_into(&a, &b, m, k, n, &mut out1);

    std::env::set_var("SAGEBWD_THREADS", "4");
    assert_eq!(linalg::thread_count(), 4);
    linalg::matmul_into(&a, &b, m, k, n, &mut out4);

    // 0 means serial (the conventional "off" value), and garbage values
    // fall back to the default rather than panicking.
    std::env::set_var("SAGEBWD_THREADS", "0");
    assert_eq!(linalg::thread_count(), 1);
    std::env::set_var("SAGEBWD_THREADS", "zero");
    assert!(linalg::thread_count() >= 1);

    match saved {
        Some(v) => std::env::set_var("SAGEBWD_THREADS", v),
        None => std::env::remove_var("SAGEBWD_THREADS"),
    }
    assert_eq!(bits(&out1), bits(&out4), "auto dispatch must be thread-count invariant");
}

#[test]
fn tensor_matmuls_ride_the_engine_bitwise() {
    // Tensor::matmul{,_nt,_tn} now route through the blocked engine; they
    // must still equal the naive per-element order bit for bit.
    let mut rng = Pcg64::new(8, 0);
    let a = Tensor::randn(&[13, 6], 1.5, &mut rng.split(0));
    let b = Tensor::randn(&[6, 9], 1.5, &mut rng.split(1));
    let c = a.matmul(&b).unwrap();
    let mut want = vec![0f32; 13 * 9];
    linalg::naive_matmul(&a.data, &b.data, 13, 6, 9, &mut want);
    assert_eq!(bits(&c.data), bits(&want));

    let bt = Tensor::randn(&[9, 6], 1.5, &mut rng.split(2));
    let cnt = a.matmul_nt(&bt).unwrap();
    linalg::naive_matmul_nt(&a.data, &bt.data, 13, 6, 9, &mut want);
    assert_eq!(bits(&cnt.data), bits(&want));

    let at = Tensor::randn(&[6, 13], 1.5, &mut rng.split(3));
    let ctn = at.matmul_tn(&b).unwrap();
    linalg::naive_matmul_tn(&at.data, &b.data, 13, 6, 9, &mut want);
    assert_eq!(bits(&ctn.data), bits(&want));
}

#[test]
fn scratch_variants_ignore_stale_pack_contents() {
    let (m, k, n) = (11, 6, 13);
    let a = randv(m * k, 60);
    let bt = randv(n * k, 61);
    let mut want = vec![0f32; m * n];
    let mut got = vec![0f32; m * n];
    linalg::naive_matmul_nt(&a, &bt, m, k, n, &mut want);
    let mut ws = Workspace::new();
    let mut pack = ws.take_f32(999); // deliberately wrong-sized, stale
    pack.iter_mut().for_each(|x| *x = f32::NAN);
    linalg::matmul_nt_scratch(&a, &bt, m, k, n, &mut got, 3, &mut pack);
    assert_eq!(bits(&want), bits(&got));
    ws.give_f32(pack);
}

#[test]
fn golden_gemm_vectors_match_bitwise() {
    // Cross-language determinism: numpy computed these in the engine's
    // documented accumulation order (make_golden.write_gemm_golden, which
    // also asserts blocked==naive bitwise on the Python side).
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/tests/data/golden_gemm.json");
    let text = std::fs::read_to_string(&path).unwrap_or_else(|e| {
        panic!(
            "missing golden fixture {} ({e}) — run `python -m compile.make_golden --gemm-only`",
            path.display()
        )
    });
    let doc = json::parse(&text).unwrap();
    for case in doc.get("f32_cases").unwrap().as_arr().unwrap() {
        let m = case.get("m").unwrap().as_usize().unwrap();
        let k = case.get("k").unwrap().as_usize().unwrap();
        let n = case.get("n").unwrap().as_usize().unwrap();
        let readv = |key: &str| -> Vec<f32> {
            case.get(key).unwrap().as_arr().unwrap()
                .iter().map(|v| v.as_f64().unwrap() as f32).collect()
        };
        let (a, b, c) = (readv("a"), readv("b"), readv("c"));
        let mut got = vec![0f32; m * n];
        linalg::gemm_nn(&a, &b, m, k, n, &mut got);
        assert_eq!(bits(&c), bits(&got), "golden gemm blocked ({m},{k},{n})");
        linalg::matmul_threads(&a, &b, m, k, n, &mut got, 4);
        assert_eq!(bits(&c), bits(&got), "golden gemm parallel ({m},{k},{n})");
    }
    let int8 = doc.get("int8_case").unwrap();
    let m = int8.get("m").unwrap().as_usize().unwrap();
    let k = int8.get("k").unwrap().as_usize().unwrap();
    let n = int8.get("n").unwrap().as_usize().unwrap();
    let readi = |key: &str| -> Vec<i64> {
        int8.get(key).unwrap().as_arr().unwrap()
            .iter().map(|v| v.as_i64().unwrap()).collect()
    };
    let a: Vec<i8> = readi("a").into_iter().map(|v| v as i8).collect();
    let b: Vec<i8> = readi("b").into_iter().map(|v| v as i8).collect();
    let want: Vec<i32> = readi("c").into_iter().map(|v| v as i32).collect();
    let mut got = vec![0i32; m * n];
    linalg::int8_gemm_nn(&a, &b, m, k, n, &mut got);
    assert_eq!(want, got, "golden i8 gemm");
}

#[test]
fn partition_is_exhaustive_and_ordered() {
    for n in [0usize, 1, 2, 7, 64, 1000] {
        for parts in [0usize, 1, 2, 3, 8, 1000] {
            let ranges = linalg::partition(n, parts);
            let mut expect = 0;
            for &(lo, hi) in &ranges {
                assert_eq!(lo, expect, "contiguous");
                assert!(hi > lo, "non-empty");
                expect = hi;
            }
            assert_eq!(expect, n, "covers 0..{n} with {parts} parts");
            assert!(ranges.len() <= parts.max(1));
            if n > 0 {
                let max = ranges.iter().map(|&(a, b)| b - a).max().unwrap();
                let min = ranges.iter().map(|&(a, b)| b - a).min().unwrap();
                assert!(max - min <= 1, "balanced within 1");
            }
        }
    }
}
