//! Finite-difference gradient checks for every native-model building
//! block (ISSUE 3 satellite): RMSNorm, QK-norm, SwiGLU MLP, causal
//! attention via the backend, tied-embedding cross-entropy, and the full
//! model end-to-end.
//!
//! Procedure (formula-identical to `python/compile/check_native_model.py`,
//! which prints the observed error floor): perturb sampled coordinates by
//! ±ε in f32, central-difference a scalar functional `J = Σ w∘out`, and
//! compare against the analytic backward fed with `dy = w`, normalizing
//! by the RMS of the analytic gradient leaf.
//!
//! Observed float32 maxima (numpy twin, seed-stable):
//!   rmsnorm 3.2e-4 · qk-norm 2.8e-4 · mlp 6.2e-4 · attention 7.7e-4 ·
//!   cross-entropy 1.3e-3 · full-model 3.5e-2 (at ε=2e-2)
//! Tolerances below are ≥3× those margins (mostly ~6–10× to absorb the
//! different RNG streams on the Rust side).

use sagebwd::model::blocks::{
    cross_entropy_bwd, cross_entropy_fwd, mlp_bwd, mlp_fwd, rmsnorm_bwd, rmsnorm_fwd,
};
use sagebwd::model::{AttnImpl, AttnVariant, Model, ModelDims};
use sagebwd::runtime::{AttentionBackend, NativeBackend, Value};
use sagebwd::tensor::{IntTensor, Tensor, Workspace};
use sagebwd::util::rng::Pcg64;

const NORM_EPS: f32 = 1e-6;

/// Central-difference check of `grad` (= dJ/d tensors[which]) against
/// `eval`.  Returns the worst `|fd − analytic| / rms(analytic)` over
/// `probes` sampled coordinates.
fn fd_vs_analytic(
    tensors: &mut [Tensor],
    which: usize,
    grad: &Tensor,
    eval: &dyn Fn(&[Tensor]) -> f64,
    probes: usize,
    eps: f32,
    rng: &mut Pcg64,
) -> f64 {
    assert_eq!(tensors[which].shape, grad.shape, "grad/tensor shape mismatch");
    let rms = (grad
        .data
        .iter()
        .map(|&x| x as f64 * x as f64)
        .sum::<f64>()
        / grad.data.len() as f64)
        .sqrt()
        + 1e-12;
    let len = tensors[which].data.len();
    let mut worst = 0f64;
    for _ in 0..probes.min(len) {
        let j = rng.below(len as u64) as usize;
        let orig = tensors[which].data[j];
        tensors[which].data[j] = orig + eps;
        let lp = eval(tensors);
        tensors[which].data[j] = orig - eps;
        let lm = eval(tensors);
        tensors[which].data[j] = orig;
        let fd = (lp - lm) / (2.0 * eps as f64);
        let err = (fd - grad.data[j] as f64).abs() / rms;
        worst = worst.max(err);
    }
    worst
}

fn randn(shape: &[usize], sigma: f32, rng: &mut Pcg64) -> Tensor {
    Tensor::randn(shape, sigma, rng)
}

fn weighted_sum(out: &Tensor, w: &Tensor) -> f64 {
    out.data
        .iter()
        .zip(&w.data)
        .map(|(&a, &b)| a as f64 * b as f64)
        .sum()
}

#[test]
fn gradcheck_rmsnorm() {
    // observed 3.2e-4 → tolerance 3e-3 (~9×)
    let mut rng = Pcg64::new(10, 0);
    let x = randn(&[8, 16], 1.0, &mut rng.split(0));
    let mut gamma = Tensor::zeros(&[16]);
    gamma.fill(1.0);
    for (g, n) in gamma.data.iter_mut().zip(randn(&[16], 0.1, &mut rng.split(1)).data) {
        *g += n;
    }
    let w = randn(&[8, 16], 1.0, &mut rng.split(2));
    let (_, cache) = rmsnorm_fwd(&x, &gamma, NORM_EPS).unwrap();
    let (dx, dgamma) = rmsnorm_bwd(&w, &gamma, &cache).unwrap();
    let eval = |ts: &[Tensor]| {
        let (y, _) = rmsnorm_fwd(&ts[0], &ts[1], NORM_EPS).unwrap();
        weighted_sum(&y, &w)
    };
    let mut tensors = vec![x, gamma];
    for (which, grad, name) in [(0usize, &dx, "dx"), (1, &dgamma, "dgamma")] {
        let err = fd_vs_analytic(&mut tensors, which, grad, &eval, 40, 5e-3, &mut rng);
        assert!(err < 3e-3, "rmsnorm {name}: fd error {err}");
    }
}

#[test]
fn gradcheck_qk_norm() {
    // The same op at head width with γ near 1 (QK-norm's regime, §4.1).
    // observed 2.8e-4 → tolerance 3e-3
    let mut rng = Pcg64::new(11, 0);
    let x = randn(&[32, 16], 1.0, &mut rng.split(0));
    let mut gamma = Tensor::zeros(&[16]);
    gamma.fill(1.0);
    for (g, n) in gamma.data.iter_mut().zip(randn(&[16], 0.05, &mut rng.split(1)).data) {
        *g += n;
    }
    let w = randn(&[32, 16], 1.0, &mut rng.split(2));
    let (_, cache) = rmsnorm_fwd(&x, &gamma, NORM_EPS).unwrap();
    let (dx, dgamma) = rmsnorm_bwd(&w, &gamma, &cache).unwrap();
    let eval = |ts: &[Tensor]| {
        let (y, _) = rmsnorm_fwd(&ts[0], &ts[1], NORM_EPS).unwrap();
        weighted_sum(&y, &w)
    };
    let mut tensors = vec![x, gamma];
    let err_x = fd_vs_analytic(&mut tensors, 0, &dx, &eval, 40, 5e-3, &mut rng);
    let err_g = fd_vs_analytic(&mut tensors, 1, &dgamma, &eval, 16, 5e-3, &mut rng);
    assert!(err_x < 3e-3, "qk-norm dx: fd error {err_x}");
    assert!(err_g < 3e-3, "qk-norm dγ: fd error {err_g}");
}

#[test]
fn gradcheck_swiglu_mlp() {
    // observed 6.2e-4 → tolerance 5e-3 (~8×)
    let mut rng = Pcg64::new(12, 0);
    let y = randn(&[8, 32], 1.0, &mut rng.split(0));
    let w_gate = randn(&[32, 64], 0.3, &mut rng.split(1));
    let w_up = randn(&[32, 64], 0.3, &mut rng.split(2));
    let w_down = randn(&[64, 32], 0.3, &mut rng.split(3));
    let w = randn(&[8, 32], 1.0, &mut rng.split(4));
    let (_, cache) = mlp_fwd(&y, &w_gate, &w_up, &w_down).unwrap();
    let (dy, dwg, dwu, dwd) =
        mlp_bwd(&w, &cache, &w_gate, &w_up, &w_down, &mut Workspace::new()).unwrap();
    let eval = |ts: &[Tensor]| {
        let (out, _) = mlp_fwd(&ts[0], &ts[1], &ts[2], &ts[3]).unwrap();
        weighted_sum(&out, &w)
    };
    let mut tensors = vec![y, w_gate, w_up, w_down];
    for (which, grad, name) in [
        (0usize, &dy, "dy"),
        (1, &dwg, "dw_gate"),
        (2, &dwu, "dw_up"),
        (3, &dwd, "dw_down"),
    ] {
        let err = fd_vs_analytic(&mut tensors, which, grad, &eval, 30, 5e-3, &mut rng);
        assert!(err < 5e-3, "mlp {name}: fd error {err}");
    }
}

#[test]
fn gradcheck_attention_via_backend() {
    // Causal FPA attention through the same backend artifact the model
    // trains with.  observed 7.7e-4 → tolerance 5e-3 (~6×)
    let mut rng = Pcg64::new(13, 0);
    let q = randn(&[32, 16], 1.0, &mut rng.split(0));
    let k = randn(&[32, 16], 1.0, &mut rng.split(1));
    let v = randn(&[32, 16], 1.0, &mut rng.split(2));
    let w = randn(&[32, 16], 1.0, &mut rng.split(3));
    let out = NativeBackend::new()
        .execute(
            "model_attn_fpa_fwdbwd_n32_d16",
            &[
                Value::F32(q.clone()),
                Value::F32(k.clone()),
                Value::F32(v.clone()),
                Value::F32(w.clone()),
            ],
        )
        .unwrap();
    let (dq, dk, dv) = (
        out[1].as_f32().unwrap().clone(),
        out[2].as_f32().unwrap().clone(),
        out[3].as_f32().unwrap().clone(),
    );
    let eval = |ts: &[Tensor]| {
        let o = NativeBackend::new()
            .execute(
                "model_attn_fpa_fwd_n32_d16",
                &[
                    Value::F32(ts[0].clone()),
                    Value::F32(ts[1].clone()),
                    Value::F32(ts[2].clone()),
                ],
            )
            .unwrap();
        weighted_sum(o[0].as_f32().unwrap(), &w)
    };
    let mut tensors = vec![q, k, v];
    for (which, grad, name) in [(0usize, &dq, "dq"), (1, &dk, "dk"), (2, &dv, "dv")] {
        let err = fd_vs_analytic(&mut tensors, which, grad, &eval, 30, 5e-3, &mut rng);
        assert!(err < 5e-3, "attention {name}: fd error {err}");
    }
}

#[test]
fn gradcheck_cross_entropy_tied_head() {
    // observed 1.3e-3 → tolerance 8e-3 (~6×)
    let mut rng = Pcg64::new(14, 0);
    let f = randn(&[16, 32], 1.0, &mut rng.split(0));
    let embed = randn(&[64, 32], 0.5, &mut rng.split(1));
    let targets: Vec<i32> = (0..16).map(|_| rng.below(64) as i32).collect();
    let (_, cache) = cross_entropy_fwd(&f, &embed, &targets).unwrap();
    let (df, dembed) = cross_entropy_bwd(&cache, &embed).unwrap();
    let eval = |ts: &[Tensor]| cross_entropy_fwd(&ts[0], &ts[1], &targets).unwrap().0;
    let mut tensors = vec![f, embed];
    let err_f = fd_vs_analytic(&mut tensors, 0, &df, &eval, 40, 1e-2, &mut rng);
    let err_e = fd_vs_analytic(&mut tensors, 1, &dembed, &eval, 40, 1e-2, &mut rng);
    assert!(err_f < 8e-3, "cross-entropy df: fd error {err_f}");
    assert!(err_e < 8e-3, "cross-entropy dembed: fd error {err_e}");
}

#[test]
fn gradcheck_full_model() {
    // End-to-end: loss gradient w.r.t. sampled coordinates of five leaves
    // spanning every block type.  FD noise dominates here (f32 loss ~4,
    // ε=2e-2): observed 3.5e-2 → tolerance 1.5e-1 (~4×).
    let dims = ModelDims::default();
    let model = Model::new(dims, AttnVariant { imp: AttnImpl::Fpa, qk_norm: true }).unwrap();
    let mut params = model.init_params(0);
    let mut rng = Pcg64::new(15, 0);
    let count = dims.microbatch * dims.seq_len;
    let draw = |rng: &mut Pcg64| -> Vec<i32> {
        (0..count).map(|_| rng.below(dims.vocab_size as u64) as i32).collect()
    };
    let shape = [dims.microbatch, dims.seq_len];
    let tokens = IntTensor::from_vec(&shape, draw(&mut rng)).unwrap();
    let targets = IntTensor::from_vec(&shape, draw(&mut rng)).unwrap();

    let out = model
        .loss_and_grads(&params, &mut NativeBackend::new(), &tokens, &targets)
        .unwrap();
    let eval = |ts: &[Tensor]| {
        model
            .loss_only(ts, &mut NativeBackend::new(), &tokens, &targets)
            .unwrap()
            .0
    };
    let names = model.param_names().to_vec();
    for leaf in ["embed", "layers.00.wq", "layers.00.q_norm", "layers.01.w_gate", "final_norm"] {
        let which = names.iter().position(|n| n == leaf).unwrap();
        let grad = out.grads[which].clone();
        let err = fd_vs_analytic(&mut params, which, &grad, &eval, 8, 2e-2, &mut rng);
        assert!(err < 1.5e-1, "full-model {leaf}: fd error {err}");
    }
}
