//! End-to-end registry + orchestrator tests on the native engine: the
//! interrupt-then-resume acceptance proof (a grid killed after N of M
//! cells, simulated via `--limit`, resumes without rewriting a single
//! finished manifest byte) and the run_cell registry-hit cache.

use std::collections::BTreeMap;
use std::path::PathBuf;

use sagebwd::coordinator::TrainerFactory;
use sagebwd::experiments::fig1_tps::{self, CellCtx};
use sagebwd::registry::{orchestrator, Registry, RunState};
use sagebwd::telemetry::Log;

fn temp_results(tag: &str) -> String {
    let dir = std::env::temp_dir().join(format!("sagebwd_regint_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir.to_string_lossy().into_owned()
}

fn native() -> TrainerFactory {
    TrainerFactory::new("native", "artifacts").unwrap()
}

/// A fig1 grid small enough for a test: 7 cells of 2–4 optimizer steps
/// on the (2, 32)-microbatch native model.
fn tiny_spec() -> orchestrator::GridSpec {
    orchestrator::grid_spec("fig1", 256, 64, 128, 3e-3, &[0]).unwrap()
}

/// Read every finished manifest's raw bytes, keyed by run-dir name.
fn manifest_bytes(results: &str) -> BTreeMap<String, Vec<u8>> {
    let runs = PathBuf::from(results).join("registry/runs");
    let mut out = BTreeMap::new();
    for e in std::fs::read_dir(&runs).unwrap() {
        let e = e.unwrap();
        let m = e.path().join("manifest.json");
        if m.is_file() {
            out.insert(
                e.file_name().to_string_lossy().into_owned(),
                std::fs::read(&m).unwrap(),
            );
        }
    }
    out
}

#[test]
fn grid_interrupt_then_resume_preserves_finished_manifests() {
    let results = temp_results("resume");
    let factory = native();
    let registry = Registry::open(&results).unwrap();
    let spec = tiny_spec();
    let log = Log::new(false);

    // "Kill" the grid after 3 of 7 cells: --limit 3 stops with the rest
    // pending, exactly like a mid-grid SIGKILL that landed between cells.
    let report =
        orchestrator::run(&factory, &registry, &results, &spec, 1, 3, false, false, None, &log).unwrap();
    assert_eq!(report.total, 7);
    assert_eq!(report.skipped, 0);
    assert_eq!(report.ran, 3, "failed: {:?}", report.failed);
    assert_eq!(report.remaining, 4);
    assert!(report.failed.is_empty(), "{:?}", report.failed);

    let before = manifest_bytes(&results);
    assert_eq!(before.len(), 3, "{:?}", before.keys());

    // Resume: the 3 finished cells are registry hits; the other 4 run.
    let report =
        orchestrator::run(&factory, &registry, &results, &spec, 1, 0, false, false, None, &log).unwrap();
    assert_eq!(report.skipped, 3);
    assert_eq!(report.ran, 4, "failed: {:?}", report.failed);
    assert!(report.failed.is_empty(), "{:?}", report.failed);

    // Acceptance proof: not one byte of a finished manifest changed.
    let after = manifest_bytes(&results);
    assert_eq!(after.len(), 7);
    for (key, bytes) in &before {
        assert_eq!(
            after.get(key),
            Some(bytes),
            "manifest {key} was rewritten across resume"
        );
    }

    // Every cell is now finished; a third invocation skips everything.
    let statuses = orchestrator::status(&factory, &registry, &spec).unwrap();
    assert!(statuses
        .iter()
        .all(|s| s.state.map(RunState::is_finished).unwrap_or(false)));
    let report =
        orchestrator::run(&factory, &registry, &results, &spec, 1, 0, false, false, None, &log).unwrap();
    assert_eq!(report.skipped, 7);
    assert_eq!(report.ran, 0);

    std::fs::remove_dir_all(&results).unwrap();
}

#[test]
fn run_cell_is_cached_by_config_hash() {
    let results = temp_results("cache");
    let factory = native();
    let registry = Registry::open(&results).unwrap();
    let log = Log::new(false);
    let ctx = CellCtx {
        factory: &factory,
        registry: &registry,
        results_dir: &results,
        experiment: "fig1",
        fresh: false,
        supervise: None,
    };

    let first = fig1_tps::run_cell(&ctx, "sage_qknorm", 64, 256, 3e-3, 0, &log).unwrap();
    // The curve views landed at the legacy path.
    let loss_csv = PathBuf::from(&results).join("fig1/sage_qknorm_tps64/train_loss.csv");
    let loss_bytes = std::fs::read(&loss_csv).unwrap();
    assert!(loss_bytes.starts_with(b"step,value\n"));

    // Second invocation: a registry hit — same outcome, no retraining
    // (the view bytes are bit-identical because they're re-materialized
    // from the same content-addressed object).
    let second = fig1_tps::run_cell(&ctx, "sage_qknorm", 64, 256, 3e-3, 0, &log).unwrap();
    assert_eq!(first.final_loss, second.final_loss);
    assert_eq!(first.diverged_at, second.diverged_at);
    assert_eq!(first.max_attn_logit, second.max_attn_logit);
    assert_eq!(std::fs::read(&loss_csv).unwrap(), loss_bytes);

    // A different seed is a different run key.
    let cfg0 = fig1_tps::cell_config("sage_qknorm", 64, 256, 3e-3, 0);
    let cfg1 = fig1_tps::cell_config("sage_qknorm", 64, 256, 3e-3, 1);
    assert_ne!(
        fig1_tps::cell_key(&factory, &cfg0).1,
        fig1_tps::cell_key(&factory, &cfg1).1
    );

    std::fs::remove_dir_all(&results).unwrap();
}

#[test]
fn grid_workers_share_thread_budget() {
    // 2 workers over 7 tiny cells: results must match the sequential
    // reference bitwise (determinism contract), and the run completes.
    let results = temp_results("jobs");
    let factory = native();
    let registry = Registry::open(&results).unwrap();
    let spec = tiny_spec();
    let log = Log::new(false);
    let report =
        orchestrator::run(&factory, &registry, &results, &spec, 2, 0, false, false, None, &log).unwrap();
    assert_eq!(report.ran, 7, "failed: {:?}", report.failed);
    assert!(report.failed.is_empty(), "{:?}", report.failed);

    // Sequential reference of one cell in a separate registry.
    let results_seq = temp_results("jobs_seq");
    let registry_seq = Registry::open(&results_seq).unwrap();
    let ctx = CellCtx {
        factory: &factory,
        registry: &registry_seq,
        results_dir: &results_seq,
        experiment: "fig1",
        fresh: false,
        supervise: None,
    };
    fig1_tps::run_cell(&ctx, "sage_qknorm", 64, 256, 3e-3, 0, &log).unwrap();

    let curve = "fig1/sage_qknorm_tps64/train_loss.csv";
    assert_eq!(
        std::fs::read(PathBuf::from(&results).join(curve)).unwrap(),
        std::fs::read(PathBuf::from(&results_seq).join(curve)).unwrap(),
        "thread-capped grid output differs from sequential reference"
    );

    std::fs::remove_dir_all(&results).unwrap();
    std::fs::remove_dir_all(&results_seq).unwrap();
}
