//! Integration tests: the Rust runtime against real AOT artifacts.
//!
//! These need `make artifacts` to have run; they skip (with a note) when
//! the artifact directory is missing so `cargo test` stays green on a
//! fresh checkout.

use sagebwd::runtime::{Runtime, Value};
use sagebwd::tensor::Tensor;
use sagebwd::util::rng::Pcg64;
use sagebwd::util::stats::{cossim, rel_l2};

fn runtime() -> Option<Runtime> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("trace_fpa.manifest.json").exists() {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        return None;
    }
    Some(Runtime::new(dir).expect("creating runtime"))
}

fn qkvdo(n: usize, d: usize, seed: u64) -> Vec<Value> {
    let mut rng = Pcg64::new(seed, 0);
    (0..4)
        .map(|i| Value::F32(Tensor::randn(&[n, d], 1.0, &mut rng.split(i))))
        .collect()
}

#[test]
fn trace_fpa_is_internally_consistent() {
    let Some(mut rt) = runtime() else { return };
    let inputs = qkvdo(128, 64, 1);
    let out = rt.execute("trace_fpa", &inputs).unwrap();
    // Output 0 is O (128, 64); P rows (output 8) sum to 1.
    let o = out[0].as_f32().unwrap();
    assert_eq!(o.shape, vec![128, 64]);
    assert!(o.is_finite());
    let p = out[8].as_f32().unwrap();
    for row in p.data.chunks(128) {
        let s: f32 = row.iter().sum();
        assert!((s - 1.0).abs() < 1e-4, "softmax row sum {s}");
    }
    // dS rows sum to zero (§6) — the K-smoothing gradient identity.
    let ds = out[10].as_f32().unwrap();
    for row in ds.data.chunks(128) {
        let s: f32 = row.iter().sum();
        assert!(s.abs() < 1e-3, "dS row sum {s}");
    }
}

#[test]
fn sage_trace_close_to_fpa_at_unit_sigma() {
    let Some(mut rt) = runtime() else { return };
    let inputs = qkvdo(128, 64, 2);
    let sage = rt.execute("trace_sage", &inputs).unwrap();
    let fpa = rt.execute("trace_fpa", &inputs).unwrap();
    for (idx, name, min_cos) in [(0, "o", 0.999), (1, "dq", 0.99), (2, "dk", 0.99), (3, "dv", 0.999)] {
        let s = sage[idx].as_f32().unwrap();
        let f = fpa[idx].as_f32().unwrap();
        let c = cossim(&s.data, &f.data);
        assert!(c > min_cos, "{name}: cossim {c}");
    }
}

#[test]
fn pseudo_trace_dp_is_exact() {
    // Table 2's structural property, via the runtime path end to end.
    let Some(mut rt) = runtime() else { return };
    let inputs = qkvdo(128, 64, 3);
    let pseudo = rt.execute("trace_pseudo", &inputs).unwrap();
    let fpa = rt.execute("trace_fpa", &inputs).unwrap();
    let rel = rel_l2(
        &pseudo[9].as_f32().unwrap().data,
        &fpa[9].as_f32().unwrap().data,
    );
    assert!(rel < 1e-6, "dP rel_l2 {rel}");
}

#[test]
fn input_validation_rejects_bad_shapes() {
    let Some(mut rt) = runtime() else { return };
    let mut inputs = qkvdo(128, 64, 4);
    inputs[0] = Value::F32(Tensor::zeros(&[64, 64])); // wrong N
    assert!(rt.execute("trace_fpa", &inputs).is_err());
    let inputs3 = &qkvdo(128, 64, 4)[..3]; // wrong arity
    assert!(rt.execute("trace_fpa", inputs3).is_err());
}

#[test]
fn missing_artifact_is_a_clean_error() {
    let Some(mut rt) = runtime() else { return };
    let err = rt.execute("no_such_artifact", &[]).unwrap_err();
    assert!(format!("{err:#}").contains("no_such_artifact"));
}

#[test]
fn init_is_deterministic_in_seed() {
    let Some(mut rt) = runtime() else { return };
    let a = rt.execute("init_sage_qknorm", &[Value::scalar_i32(7)]).unwrap();
    let b = rt.execute("init_sage_qknorm", &[Value::scalar_i32(7)]).unwrap();
    let c = rt.execute("init_sage_qknorm", &[Value::scalar_i32(8)]).unwrap();
    let (a0, b0, c0) = (
        a[0].as_f32().unwrap(),
        b[0].as_f32().unwrap(),
        c[0].as_f32().unwrap(),
    );
    assert_eq!(a0.data, b0.data);
    assert_ne!(a0.data, c0.data);
}

#[test]
fn grad_step_loss_is_sane_and_grads_flow() {
    let Some(mut rt) = runtime() else { return };
    let params = rt
        .execute("init_sage_qknorm", &[Value::scalar_i32(0)])
        .unwrap();
    let exe = rt.load("grad_step_sage_qknorm").unwrap();
    let m = &exe.manifest;
    let tok_spec = m.input("tokens").unwrap();
    let (b, n) = (tok_spec.shape[0], tok_spec.shape[1]);
    let vocab = m.meta.get("vocab_size").unwrap().as_i64().unwrap() as i32;

    let mut rng = Pcg64::new(0, 9);
    let tokens: Vec<i32> = (0..b * n).map(|_| rng.below(vocab as u64) as i32).collect();
    let targets: Vec<i32> = (0..b * n).map(|_| rng.below(vocab as u64) as i32).collect();
    let mut inputs = params.clone();
    inputs.push(Value::I32(
        sagebwd::tensor::IntTensor::from_vec(&[b, n], tokens).unwrap(),
    ));
    inputs.push(Value::I32(
        sagebwd::tensor::IntTensor::from_vec(&[b, n], targets).unwrap(),
    ));
    let out = exe.execute(&inputs).unwrap();
    let loss = out[0].as_f32().unwrap().item();
    // Fresh init on random targets ⇒ loss ≈ ln(vocab)=6.24.
    assert!((loss - (vocab as f32).ln()).abs() < 1.0, "loss {loss}");
    // Most gradient leaves are nonzero.
    let nonzero = out[1..]
        .iter()
        .filter(|v| v.as_f32().map(|t| t.max_abs() > 0.0).unwrap_or(false))
        .count();
    assert!(nonzero >= out.len() - 3, "only {nonzero} nonzero grads");
}

#[test]
fn apply_step_moves_params() {
    let Some(mut rt) = runtime() else { return };
    let params = rt
        .execute("init_sage_qknorm", &[Value::scalar_i32(0)])
        .unwrap();
    let n = params.len();
    let zeros: Vec<Value> = params
        .iter()
        .map(|p| Value::F32(Tensor::zeros(p.shape())))
        .collect();
    let ones: Vec<Value> = params
        .iter()
        .map(|p| {
            let mut t = Tensor::zeros(p.shape());
            t.fill(1e-3);
            Value::F32(t)
        })
        .collect();
    let mut inputs = Vec::with_capacity(4 * n + 2);
    inputs.extend(params.iter().cloned());
    inputs.extend(zeros.iter().cloned());
    inputs.extend(zeros.iter().cloned());
    inputs.extend(ones.iter().cloned());
    inputs.push(Value::scalar_f32(1e-2));
    inputs.push(Value::scalar_i32(1));
    let out = rt.execute("apply_step_qknorm", &inputs).unwrap();
    assert_eq!(out.len(), 3 * n);
    // Params moved opposite the (positive) gradient.
    let p0 = params[0].as_f32().unwrap();
    let p1 = out[0].as_f32().unwrap();
    let mean_delta: f32 =
        p1.data.iter().zip(&p0.data).map(|(a, b)| a - b).sum::<f32>() / p0.len() as f32;
    assert!(mean_delta < 0.0, "mean param delta {mean_delta}");
}
