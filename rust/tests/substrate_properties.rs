//! Cross-module property tests over the Rust substrates (no artifacts
//! needed): data pipeline determinism/sharding, JSON fuzz-ish roundtrip,
//! checkpoint fuzz, schedule × accumulator interplay.

use sagebwd::coordinator::{microbatches_for_tps, Checkpoint, CosineSchedule, GradAccumulator};
use sagebwd::data::{Batcher, Tokenizer};
use sagebwd::tensor::Tensor;
use sagebwd::util::json::{self, Json};
use sagebwd::util::quickcheck::{check, check_with, Config, Gen};

#[test]
fn json_roundtrip_random_documents() {
    fn random_json(g: &mut Gen, depth: usize) -> Json {
        match if depth == 0 { g.usize_in(0, 3) } else { g.usize_in(0, 5) } {
            0 => Json::Null,
            1 => Json::Bool(g.bool()),
            2 => Json::Num((g.i64_in(-1_000_000, 1_000_000) as f64) / 64.0),
            3 => Json::Str(g.string(12)),
            4 => Json::Arr((0..g.usize_in(0, 4)).map(|_| random_json(g, depth - 1)).collect()),
            _ => {
                let mut o = Json::obj();
                for _ in 0..g.usize_in(0, 4) {
                    o.set(&g.string(8), random_json(g, depth - 1));
                }
                o
            }
        }
    }
    check("json roundtrip", |g| {
        let doc = random_json(g, 3);
        let text = doc.to_string();
        let back = json::parse(&text).map_err(|e| format!("parse failed on {text}: {e}"))?;
        if back != doc {
            return Err(format!("roundtrip mismatch: {text}"));
        }
        Ok(())
    });
}

#[test]
fn batcher_deterministic_and_shards_disjoint() {
    check_with(Config { cases: 12, seed: 7 }, "batcher", |g| {
        let seed = g.usize_in(0, 1000) as u64;
        let batch = g.usize_in(1, 4);
        let seq = *g.choose(&[8usize, 16, 32]);
        let collect = |shard: u64| {
            let mut b = Batcher::new(Tokenizer::bytes_only(), seed, shard, batch, seq);
            (0..3).map(|_| b.next_batch().unwrap()).collect::<Vec<_>>()
        };
        if collect(0) != collect(0) {
            return Err("nondeterministic".into());
        }
        if collect(0) == collect(1) {
            return Err("shards overlap".into());
        }
        Ok(())
    });
}

#[test]
fn batcher_targets_shifted_by_one() {
    check_with(Config { cases: 10, seed: 3 }, "shift", |g| {
        let seq = *g.choose(&[8usize, 16]);
        let mut b = Batcher::new(Tokenizer::bytes_only(), g.usize_in(0, 99) as u64, 0, 1, seq);
        let batch = b.next_batch().unwrap();
        if batch.tokens.data[1..] != batch.targets.data[..seq - 1] {
            return Err("targets are not next-token".into());
        }
        Ok(())
    });
}

#[test]
fn checkpoint_roundtrip_random_tensors() {
    check_with(Config { cases: 20, seed: 11 }, "checkpoint", |g| {
        let n = g.usize_in(0, 5);
        let tensors: Vec<(String, Tensor)> = (0..n)
            .map(|i| {
                let dims = (0..g.usize_in(0, 3))
                    .map(|_| g.usize_in(1, 6))
                    .collect::<Vec<_>>();
                let numel = dims.iter().product();
                (
                    format!("t{i}.{}", g.string(6).replace('"', "q")),
                    Tensor::from_vec(&dims, g.vec_f32(numel, 2.0)).unwrap(),
                )
            })
            .collect();
        let ckpt = Checkpoint {
            step: g.usize_in(0, 1 << 20) as u64,
            tokens_seen: g.usize_in(0, 1 << 24) as u64,
            rng: None,
            tensors,
        };
        let path = std::env::temp_dir().join(format!(
            "sagebwd_qc_{}_{}.ckpt",
            std::process::id(),
            g.usize_in(0, usize::MAX / 2)
        ));
        ckpt.save(&path).map_err(|e| e.to_string())?;
        let back = Checkpoint::load(&path).map_err(|e| e.to_string())?;
        std::fs::remove_file(&path).ok();
        if back != ckpt {
            return Err("roundtrip mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn tps_accounting_is_exact() {
    // microbatches × microbatch_tokens == tokens_per_step, never rounded.
    check("tps exact", |g: &mut Gen| {
        let micro = g.usize_in(1, 8) as u64;
        let seq = *g.choose(&[32u64, 64, 128]);
        let k = g.usize_in(1, 64) as u64;
        let tps = k * micro * seq;
        let n = microbatches_for_tps(tps, micro, seq).map_err(|e| e.to_string())?;
        if n * micro * seq != tps {
            return Err(format!("{n} × {micro} × {seq} ≠ {tps}"));
        }
        Ok(())
    });
}

#[test]
fn warmup_then_decay_crosses_peak_once() {
    check_with(Config { cases: 50, seed: 23 }, "single peak", |g| {
        let warmup = g.usize_in(1, 30) as u64;
        let total = warmup + g.usize_in(2, 200) as u64;
        let s = CosineSchedule::new(1e-3, warmup, total, 0.05).unwrap();
        // Strictly increasing before warmup end, non-increasing after.
        for step in 1..warmup {
            if s.lr(step) <= s.lr(step - 1) {
                return Err(format!("warmup not increasing at {step}"));
            }
        }
        for step in warmup + 1..total {
            if s.lr(step) > s.lr(step - 1) + 1e-15 {
                return Err(format!("decay increased at {step}"));
            }
        }
        Ok(())
    });
}

#[test]
fn accumulator_average_bounded_by_inputs() {
    // Mean gradient is within [min, max] of the accumulated microbatches
    // elementwise — no overflow/accumulation bug amplifies values.
    check("mean bounded", |g: &mut Gen| {
        let len = g.usize_in(1, 24);
        let k = g.usize_in(1, 6);
        let micro: Vec<Vec<f32>> = (0..k).map(|_| g.vec_f32(len, 3.0)).collect();
        let mut acc = GradAccumulator::new(&[vec![len]]);
        for m in &micro {
            acc.add(1.0, &[Tensor::from_vec(&[len], m.clone()).unwrap()])
                .map_err(|e| e.to_string())?;
        }
        let (_, grads) = acc.take_mean().map_err(|e| e.to_string())?;
        for i in 0..len {
            let lo = micro.iter().map(|m| m[i]).fold(f32::INFINITY, f32::min);
            let hi = micro.iter().map(|m| m[i]).fold(f32::NEG_INFINITY, f32::max);
            let v = grads[0].data[i];
            if v < lo - 1e-4 || v > hi + 1e-4 {
                return Err(format!("mean {v} outside [{lo}, {hi}]"));
            }
        }
        Ok(())
    });
}

#[test]
fn bpe_tokenizer_roundtrip_random_ascii() {
    let mut sample = String::new();
    let mut c = sagebwd::data::Corpus::new(99, 0);
    c.fill_text(&mut sample, 30_000);
    let tok = Tokenizer::train(&sample, 384).unwrap();
    check_with(Config { cases: 40, seed: 31 }, "bpe roundtrip", |g| {
        let text = g.string(200);
        let ids = tok.encode(&text);
        let back = tok.decode(&ids).map_err(|e| e.to_string())?;
        if back != text {
            return Err(format!("roundtrip mismatch on {text:?}"));
        }
        Ok(())
    });
}
