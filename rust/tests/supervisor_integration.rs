//! End-to-end tests for the fault-tolerant training supervisor
//! (DESIGN.md §16) on the native engine: the crash/resume bitwise
//! acceptance proof, one test per injected fault class (NaN gradients,
//! worker panic, torn artifact write), recovery-budget exhaustion, and
//! the grid orchestrator's `--retry-diverged` mode.

use std::collections::BTreeMap;
use std::path::PathBuf;

use sagebwd::config::TrainConfig;
use sagebwd::coordinator::{supervisor, RunStatus, SupervisorConfig, TrainerFactory};
use sagebwd::experiments::fig1_tps;
use sagebwd::registry::{orchestrator, Registry, RunState};
use sagebwd::telemetry::Log;
use sagebwd::util::faults;
use sagebwd::util::json::{schema, Json};

fn temp_results(tag: &str) -> String {
    let dir = std::env::temp_dir().join(format!("sagebwd_supint_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir.to_string_lossy().into_owned()
}

fn native() -> TrainerFactory {
    TrainerFactory::new("native", "artifacts").unwrap()
}

/// A 6-step config on the (2, 32)-microbatch native model: 2 microbatches
/// per optimizer step, small enough for a test, long enough for periodic
/// checkpoints at steps 2/4/6.
fn base_cfg() -> TrainConfig {
    TrainConfig {
        variant: "sage_qknorm".to_string(),
        steps: 6,
        tokens_per_step: 128,
        warmup_steps: 1,
        peak_lr: 3e-3,
        min_lr_frac: 0.1,
        seed: 0,
        checkpoint_every: 0,
        log_every: 0,
        clip_norm: 0.0,
        grad_noise_sigma: 0.0,
        ..TrainConfig::default()
    }
}

fn sup(save_every: u64, max_recoveries: u64) -> SupervisorConfig {
    SupervisorConfig {
        save_every,
        max_recoveries,
        ..SupervisorConfig::default()
    }
}

/// Read every manifest's raw bytes, keyed by run-dir name.
fn manifest_bytes(results: &str) -> BTreeMap<String, Vec<u8>> {
    let runs = PathBuf::from(results).join("registry/runs");
    let mut out = BTreeMap::new();
    for e in std::fs::read_dir(&runs).unwrap() {
        let e = e.unwrap();
        let m = e.path().join("manifest.json");
        if m.is_file() {
            out.insert(
                e.file_name().to_string_lossy().into_owned(),
                std::fs::read(&m).unwrap(),
            );
        }
    }
    out
}

#[test]
fn crash_resume_is_bitwise_identical() {
    let factory = native();
    let log = Log::new(false);
    let cfg = base_cfg();
    let (_, key) = fig1_tps::cell_key(&factory, &cfg);

    // Reference: one uninterrupted supervised run.
    let res_a = temp_results("full");
    let reg_a = Registry::open(&res_a).unwrap();
    let view_a = PathBuf::from(&res_a).join("train/ref");
    let out = supervisor::run_supervised(
        &factory, &reg_a, "train", "ref", &cfg, &sup(2, 0), &view_a, &log,
    )
    .unwrap();
    assert!(matches!(out.report.status, RunStatus::Completed));
    assert!(out.recoveries.is_empty());
    assert_eq!(out.report.steps_done, 6);
    assert_eq!(out.resumed_from, None);

    // Interrupted: halt after 3 steps (the simulated crash — the manifest
    // is left `running` with the step-2 checkpoint recorded), then run
    // the identical command again.
    let res_b = temp_results("crash");
    let reg_b = Registry::open(&res_b).unwrap();
    let view_b = PathBuf::from(&res_b).join("train/ref");
    let halted = supervisor::run_supervised(
        &factory,
        &reg_b,
        "train",
        "ref",
        &cfg,
        &SupervisorConfig {
            halt_after: Some(3),
            ..sup(2, 0)
        },
        &view_b,
        &log,
    )
    .unwrap();
    assert!(halted.halted);
    assert_eq!(
        reg_b.load_run(&key).unwrap().unwrap().status,
        RunState::Running
    );

    let resumed = supervisor::run_supervised(
        &factory, &reg_b, "train", "ref", &cfg, &sup(2, 0), &view_b, &log,
    )
    .unwrap();
    assert!(!resumed.halted);
    assert_eq!(resumed.resumed_from, Some(2));
    assert!(matches!(resumed.report.status, RunStatus::Completed));
    assert_eq!(resumed.report.steps_done, 6);

    // Bitwise acceptance proof: artifacts are content-addressed, so equal
    // hashes are equal bytes — the killed-and-resumed run re-emitted the
    // exact metric curves and final checkpoint of the uninterrupted one.
    let ma = reg_a.load_run(&key).unwrap().unwrap();
    let mb = reg_b.load_run(&key).unwrap().unwrap();
    assert_eq!(ma.status, RunState::Complete);
    assert_eq!(mb.status, RunState::Complete);
    for name in ["train_loss.csv", "max_attn_logit.csv", "tokens.csv", "ckpt_000006"] {
        let a = ma
            .artifact(name)
            .unwrap_or_else(|| panic!("{name} missing from reference run"));
        let b = mb
            .artifact(name)
            .unwrap_or_else(|| panic!("{name} missing from resumed run"));
        assert_eq!(a.sha256, b.sha256, "{name} differs across kill/resume");
    }

    std::fs::remove_dir_all(&res_a).unwrap();
    std::fs::remove_dir_all(&res_b).unwrap();
}

#[test]
fn nan_fault_recovers_via_lr_backoff() {
    let factory = native();
    let log = Log::new(false);
    let cfg = base_cfg();
    let results = temp_results("nan");
    let registry = Registry::open(&results).unwrap();
    let view = PathBuf::from(&results).join("train/nan");

    // Poison one gradient element at step 3; the checkpoint at step 2 is
    // the rollback point, and the ladder's first stage backs off the LR.
    faults::install(faults::parse_plan("seed=1; nan@3").unwrap());
    let out = supervisor::run_supervised(
        &factory, &registry, "train", "nan", &cfg, &sup(2, 2), &view, &log,
    )
    .unwrap();
    faults::clear();

    assert!(matches!(out.report.status, RunStatus::Completed));
    assert_eq!(out.report.steps_done, 6);
    assert_eq!(out.recoveries.len(), 1, "{:?}", out.recoveries);
    let rec = &out.recoveries[0];
    assert_eq!(rec.attempt, 1);
    assert_eq!(rec.action, "lr_backoff");
    assert_eq!(rec.at_step, 3);
    assert_eq!(rec.resume_step, 2);
    assert!(
        rec.reason.contains("non-finite gradient"),
        "reason should name the poisoned site: {}",
        rec.reason
    );
    assert!((out.effective.peak_lr - cfg.peak_lr * 0.5).abs() < 1e-15);

    // The recovery and its count are on the finished manifest.
    let (_, key) = fig1_tps::cell_key(&factory, &cfg);
    let m = registry.load_run(&key).unwrap().unwrap();
    assert_eq!(m.status, RunState::Complete);
    assert_eq!(m.recoveries.len(), 1);
    assert_eq!(schema::u64_field(&m.summary, "recoveries").unwrap(), 1);
    assert_eq!(
        schema::nullable_f64_field(&m.summary, "diverged_at").unwrap(),
        None
    );

    std::fs::remove_dir_all(&results).unwrap();
}

#[test]
fn injected_worker_panic_retries_from_checkpoint() {
    let factory = native();
    let log = Log::new(false);
    let cfg = base_cfg();
    let results = temp_results("panic");
    let registry = Registry::open(&results).unwrap();
    let view = PathBuf::from(&results).join("train/panic");

    // Panic a fan-out worker during step 2: train_step errors (a hard
    // engine fault, not divergence), so the supervisor retries the same
    // config from the last good checkpoint.
    faults::install(faults::parse_plan("panic@2").unwrap());
    let out = supervisor::run_supervised(
        &factory, &registry, "train", "panic", &cfg, &sup(2, 2), &view, &log,
    )
    .unwrap();
    faults::clear();

    assert!(matches!(out.report.status, RunStatus::Completed));
    assert_eq!(out.recoveries.len(), 1, "{:?}", out.recoveries);
    let rec = &out.recoveries[0];
    assert_eq!(rec.action, "retry");
    assert_eq!(rec.at_step, 2);
    assert_eq!(rec.resume_step, 2);
    assert!(
        rec.reason.contains("panicked"),
        "reason should carry the worker panic: {}",
        rec.reason
    );
    // A retry changes nothing about the effective config.
    assert_eq!(out.effective.peak_lr, cfg.peak_lr);
    assert_eq!(out.effective.tokens_per_step, cfg.tokens_per_step);
    assert_eq!(out.effective.variant, cfg.variant);

    std::fs::remove_dir_all(&results).unwrap();
}

#[test]
fn torn_checkpoint_write_is_detected_and_repaired() {
    let factory = native();
    let log = Log::new(false);
    let cfg = base_cfg();
    let results = temp_results("torn");
    let registry = Registry::open(&results).unwrap();
    let view = PathBuf::from(&results).join("train/torn");

    // Tear the first registry artifact write — the step-2 checkpoint.
    // The verified read-back catches it, re-puts the bytes, and records
    // a `rewrite_artifact` recovery (bookkeeping, not a rollback).
    faults::install(faults::parse_plan("torn@1").unwrap());
    let out = supervisor::run_supervised(
        &factory, &registry, "train", "torn", &cfg, &sup(2, 2), &view, &log,
    )
    .unwrap();
    faults::clear();

    assert!(matches!(out.report.status, RunStatus::Completed));
    assert_eq!(out.recoveries.len(), 1, "{:?}", out.recoveries);
    let rec = &out.recoveries[0];
    assert_eq!(rec.action, "rewrite_artifact");
    assert_eq!(rec.at_step, 2);
    assert_eq!(rec.resume_step, 2);

    // Every checkpoint object on the manifest now verifies.
    let (_, key) = fig1_tps::cell_key(&factory, &cfg);
    let m = registry.load_run(&key).unwrap().unwrap();
    assert_eq!(m.status, RunState::Complete);
    for a in m.artifacts.iter().filter(|a| a.name.starts_with("ckpt_")) {
        registry
            .read_object(&a.sha256)
            .unwrap_or_else(|e| panic!("{} unreadable after repair: {e:#}", a.name));
    }

    std::fs::remove_dir_all(&results).unwrap();
}

#[test]
fn recovery_budget_exhaustion_finishes_diverged() {
    let factory = native();
    let log = Log::new(false);
    // A ceiling every step crosses: the run diverges immediately, burns
    // both rollbacks (LR backoff, then TPS halving), and must then finish
    // `diverged` with the full ladder walk on the manifest.
    let cfg = TrainConfig {
        max_attn_logit_ceiling: 1e-6,
        ..base_cfg()
    };
    let results = temp_results("exhaust");
    let registry = Registry::open(&results).unwrap();
    let view = PathBuf::from(&results).join("train/exhaust");

    let out = supervisor::run_supervised(
        &factory, &registry, "train", "exhaust", &cfg, &sup(0, 2), &view, &log,
    )
    .unwrap();

    assert!(matches!(out.report.status, RunStatus::Diverged { at_step: 0 }));
    assert_eq!(out.recoveries.len(), 2, "{:?}", out.recoveries);
    assert_eq!(out.recoveries[0].action, "lr_backoff");
    assert_eq!(out.recoveries[1].action, "halve_tps");
    assert_eq!(out.recoveries[1].tokens_per_step, cfg.tokens_per_step / 2);
    // save_every 0: the rollback point is the in-memory init snapshot.
    assert_eq!(out.recoveries[0].resume_step, 0);
    assert_eq!(out.recoveries[1].resume_step, 0);

    let (_, key) = fig1_tps::cell_key(&factory, &cfg);
    let m = registry.load_run(&key).unwrap().unwrap();
    assert_eq!(m.status, RunState::Diverged);
    assert_eq!(m.recoveries.len(), 2);
    assert_eq!(schema::u64_field(&m.summary, "recoveries").unwrap(), 2);
    assert_eq!(
        schema::nullable_f64_field(&m.summary, "diverged_at").unwrap(),
        Some(0.0)
    );

    std::fs::remove_dir_all(&results).unwrap();
}

#[test]
fn grid_retry_diverged_reruns_only_diverged_cells() {
    let results = temp_results("grid");
    let factory = native();
    let registry = Registry::open(&results).unwrap();
    let spec = orchestrator::grid_spec("fig1", 256, 64, 128, 3e-3, &[0]).unwrap();
    let log = Log::new(false);

    // Manufacture finished manifests for all 7 cells: cell 0 diverged,
    // the rest complete (summaries shaped like real training cells, so
    // the registry-hit path can decode them).
    for (i, cell) in spec.cells.iter().enumerate() {
        let cfg = fig1_tps::cell_config(
            &cell.variant,
            cell.tps,
            spec.token_budget,
            spec.peak_lr,
            cell.seed,
        );
        let (config, key) = fig1_tps::cell_key(&factory, &cfg);
        let mut run = registry
            .begin_run_keyed("fig1", &cell.label, config, key)
            .unwrap();
        let diverged = i == 0;
        run.set_summary(Json::from_pairs(vec![
            (
                "diverged_at",
                if diverged { Json::from(1.0) } else { Json::Null },
            ),
            ("final_loss", Json::from(5.0)),
            ("max_attn_logit", Json::from(3.0)),
        ]));
        run.finish(if diverged {
            RunState::Diverged
        } else {
            RunState::Complete
        })
        .unwrap();
    }
    let before = manifest_bytes(&results);
    assert_eq!(before.len(), 7);

    // Plain resume: every cell is finished, nothing runs.
    let report = orchestrator::run(
        &factory, &registry, &results, &spec, 1, 0, false, false, None, &log,
    )
    .unwrap();
    assert_eq!(report.skipped, 7);
    assert_eq!(report.ran, 0);
    assert_eq!(
        manifest_bytes(&results),
        before,
        "plain resume must not touch finished manifests"
    );

    // --retry-diverged under the supervisor: exactly the diverged cell
    // reruns; the 6 complete manifests stay byte-identical.
    let report = orchestrator::run(
        &factory,
        &registry,
        &results,
        &spec,
        1,
        0,
        false,
        true,
        Some(sup(2, 2)),
        &log,
    )
    .unwrap();
    assert_eq!(report.skipped, 6);
    assert_eq!(report.ran, 1, "failed: {:?}", report.failed);
    assert!(report.failed.is_empty(), "{:?}", report.failed);

    let cell0 = &spec.cells[0];
    let cfg0 = fig1_tps::cell_config(
        &cell0.variant,
        cell0.tps,
        spec.token_budget,
        spec.peak_lr,
        cell0.seed,
    );
    let (_, key0) = fig1_tps::cell_key(&factory, &cfg0);
    let dir0 = key0[..16].to_string();
    let after = manifest_bytes(&results);
    for (name, bytes) in &before {
        if *name == dir0 {
            assert_ne!(
                after.get(name),
                Some(bytes),
                "diverged cell was not retrained"
            );
        } else {
            assert_eq!(
                after.get(name),
                Some(bytes),
                "complete manifest {name} was rewritten by --retry-diverged"
            );
        }
    }
    // This time it trained for real — and this config genuinely trains
    // clean, so the retry converts `diverged` into `complete`.
    let m0 = registry.load_run(&key0).unwrap().unwrap();
    assert_eq!(m0.status, RunState::Complete);
    assert_eq!(
        schema::nullable_f64_field(&m0.summary, "diverged_at").unwrap(),
        None
    );

    std::fs::remove_dir_all(&results).unwrap();
}
