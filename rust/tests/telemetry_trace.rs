//! Tier-1 observability gate (DESIGN.md §14): the tracing/probe layer's
//! hard contracts on the native engine.
//!
//! * **Determinism** — `train_loss` is bitwise-identical with tracing
//!   off, tracing on, and tracing + per-step quantization-error probes:
//!   observability must never perturb numerics.
//! * **Coverage** — a traced run records the whole span hierarchy
//!   (`train_step → fwd/bwd → layer → attention → GEMM family`), the
//!   workspace/backend counters, and all seven `qerr_*` metric series.
//! * **dS-dominance** — at the paper's trained-regime surrogate (Table
//!   2's grown Q/K norms) the probe reports `qerr_ds` rel-L2 above
//!   `qerr_pv`, reproducing insight (ii) directionally.
//! * **Schema** — the emitted `sagebwd-trace-v1` JSONL round-trips
//!   losslessly and the strict parser rejects malformed event logs
//!   (checked against the committed `trace_fixture.jsonl`).
//!
//! Tracing and probe toggles are process-global, so every test that
//! flips them serializes on one mutex and restores the off state before
//! releasing it; the pure-parser test needs no global state.

use std::sync::Mutex;

use sagebwd::config::TrainConfig;
use sagebwd::coordinator::TrainerFactory;
use sagebwd::experiments::common::gaussian_qkvdo;
use sagebwd::kernels::{fpa_bwd, sage_bwd, AttnConfig};
use sagebwd::telemetry::trace::{self, TraceReport};
use sagebwd::telemetry::{qerr, Log, Metrics};

/// Serializes the tests that toggle the process-global trace/qerr state.
static GATE: Mutex<()> = Mutex::new(());

fn cfg(steps: u64, tps: u64) -> TrainConfig {
    TrainConfig {
        variant: "sage_qknorm".into(),
        steps,
        tokens_per_step: tps,
        warmup_steps: 1,
        peak_lr: 3e-3,
        min_lr_frac: 0.1,
        seed: 0,
        checkpoint_every: 0,
        log_every: 0,
        clip_norm: 0.0,
        grad_noise_sigma: 0.0,
        ..TrainConfig::default()
    }
}

/// One short native run under the given observability settings; returns
/// the trainer's metric registry with the globals restored to off.
fn train(trace_on: bool, qerr_every: u64) -> Metrics {
    trace::set_enabled(trace_on);
    qerr::set_every(qerr_every);
    trace::reset();
    let factory = TrainerFactory::new("native", "artifacts").unwrap();
    let mut t = factory.trainer(cfg(3, 64)).unwrap();
    let mut b = t.make_batcher(512, 4).unwrap();
    t.run(&mut b, &Log::new(false)).unwrap();
    trace::set_enabled(false);
    qerr::set_every(0);
    t.metrics
}

fn loss_bits(m: &Metrics) -> Vec<(u64, u64)> {
    m.get("train_loss")
        .expect("train_loss series present")
        .points
        .iter()
        .map(|&(step, v)| (step, v.to_bits()))
        .collect()
}

#[test]
fn tracing_and_probes_do_not_perturb_numerics() {
    let _g = GATE.lock().unwrap_or_else(|p| p.into_inner());
    let off = loss_bits(&train(false, 0));
    let on = loss_bits(&train(true, 0));
    let probed = loss_bits(&train(true, 1));
    assert_eq!(off.len(), 3);
    assert_eq!(off, on, "trace on vs off must be bitwise identical");
    assert_eq!(off, probed, "qerr probes must not perturb the curve");
}

#[test]
fn traced_run_covers_hierarchy_counters_and_qerr_series() {
    let _g = GATE.lock().unwrap_or_else(|p| p.into_inner());
    trace::set_enabled(true);
    qerr::set_every(1);
    trace::reset();
    let factory = TrainerFactory::new("native", "artifacts").unwrap();
    let mut t = factory.trainer(cfg(2, 64)).unwrap();
    let mut b = t.make_batcher(512, 4).unwrap();
    t.run(&mut b, &Log::new(false)).unwrap();
    let report = trace::take_report();
    trace::set_enabled(false);
    qerr::set_every(0);

    // Span hierarchy: every level of the trainer → kernel stack shows up.
    let span = |n: &str| report.spans.iter().find(|s| s.name == n);
    for name in [
        "train_step",
        "fwd",
        "bwd",
        "layer",
        "attention",
        "qerr_probe",
        "execute_many",
        "gemm_nn",
        "i8_gemm_nn",
    ] {
        assert!(
            span(name).is_some(),
            "missing span {name:?}; got {:?}",
            report.spans.iter().map(|s| &s.name).collect::<Vec<_>>()
        );
    }
    let ts = span("train_step").unwrap();
    assert_eq!(ts.calls, 2);
    assert!(ts.parent.is_none());
    assert!(ts.self_ns <= ts.total_ns && ts.min_ns <= ts.max_ns);
    assert_eq!(span("fwd").unwrap().parent.as_deref(), Some("train_step"));
    assert_eq!(span("bwd").unwrap().parent.as_deref(), Some("train_step"));
    assert_eq!(span("layer").unwrap().parent.as_deref(), Some("fwd"));

    // Counters: workspace arena traffic and execute_many fan-out.
    let counter = |n: &str| {
        report
            .counters
            .iter()
            .find(|c| c.name == n)
            .map(|c| c.value)
    };
    assert!(counter("ws_miss").unwrap_or(0) > 0, "{:?}", report.counters);
    assert!(counter("ws_high_water_bytes").unwrap_or(0) > 0);
    assert!(counter("exec_many_batches").unwrap_or(0) > 0);
    assert!(counter("exec_many_calls").unwrap_or(0) > 0);

    // qerr series: all seven matmuls recorded on every sampled step,
    // finite on the rel-L2 channel.
    for name in ["qk", "pv", "dv", "dp", "ds", "dq", "dk"] {
        let rel = t.metrics.get(&format!("qerr_{name}"));
        let cos = t.metrics.get(&format!("qerr_{name}_cos"));
        assert!(rel.is_some() && cos.is_some(), "missing qerr_{name} series");
        assert_eq!(rel.unwrap().points.len(), 2, "one point per sampled step");
        assert!(rel.unwrap().points.iter().all(|&(_, v)| v.is_finite()));
    }
    // dP is the one matmul the kernel keeps in FP (insight (ii)'s exact
    // Table 2 row): its only error is tiled-vs-naive accumulation order,
    // orders of magnitude below any INT8 product.
    let dp = t.metrics.get("qerr_dp").unwrap().max_value().unwrap();
    let qk = t.metrics.get("qerr_qk").unwrap().max_value().unwrap();
    assert!(dp < 1e-4, "FP dP drifted: rel-L2 {dp}");
    assert!(qk > dp, "INT8 QK must sit above the FP dP floor");

    // The emitted JSONL round-trips losslessly and renders.
    let text = report.to_jsonl();
    let parsed = TraceReport::parse_jsonl(&text).unwrap();
    assert_eq!(parsed, report);
    let table = report.render_table();
    assert!(table.contains("train_step") && table.contains("ws_miss"));
}

/// Insight (ii) directionally: the dS error spike is a trained-regime
/// phenomenon, so the gate pins Table 2's surrogate (grown Q/K norms
/// σ≈4, small upstream dO — DESIGN.md §6) where the spike is structural
/// (rel-L2 ≈ 0.1–0.2 vs ≈ 0.03–0.05 for O).  At the QK-norm training
/// operating point the softmax is mild and the ordering is not
/// guaranteed — the training-run test above therefore only checks the
/// FP-dP floor, and this one checks the dominance where the paper
/// claims it.
#[test]
fn qerr_probe_reproduces_ds_dominance_at_trained_regime() {
    let _g = GATE.lock().unwrap_or_else(|p| p.into_inner());
    qerr::set_every(1);
    qerr::begin_step(0);
    assert!(qerr::active(), "step 0 of every-1 sampling must be active");
    let [q, k, v, do_] = gaussian_qkvdo(128, 64, 4.0, 4.0, 1.0, 0.02, 77);
    let cfg = AttnConfig {
        causal: true,
        ..AttnConfig::default()
    };
    let sage = sage_bwd(&q, &k, &v, &do_, &cfg).unwrap();
    let exact = fpa_bwd(&q, &k, &v, &do_, cfg.causal).unwrap();
    qerr::probe(&sage, &exact, cfg.causal);
    let step = qerr::take_step();
    qerr::set_every(0);

    let get = |name: &str| {
        step.iter()
            .find(|(s, _, _)| *s == name)
            .map(|&(_, rel, cos)| (rel, cos))
            .unwrap_or_else(|| panic!("missing {name} in {step:?}"))
    };
    let (ds, ds_cos) = get("ds");
    let (pv, pv_cos) = get("pv");
    let (dp, _) = get("dp");
    assert!(
        ds > pv,
        "dS-dominance (Table 2 / insight (ii)): rel-L2 ds {ds} must exceed pv {pv}"
    );
    assert!(dp < 1e-4, "FP dP must be exact up to accumulation order: {dp}");
    assert!(
        ds_cos < pv_cos,
        "the worse rel-L2 must pair with the worse cossim: ds {ds_cos} vs pv {pv_cos}"
    );
    assert!(ds.is_finite() && (0.0..=1.0).contains(&pv_cos));
}

#[test]
fn trace_fixture_parses_and_corruptions_are_rejected() {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/data/trace_fixture.jsonl");
    let text = std::fs::read_to_string(&path).unwrap();
    let report = TraceReport::parse_jsonl(&text).unwrap();
    assert_eq!(report.threads, 2);
    assert_eq!(report.spans.len(), 3);
    assert_eq!(report.counters.len(), 2);
    assert_eq!(report.spans[1].parent.as_deref(), Some("train_step"));

    // Unknown key on an event line.
    let bad = text.replace(
        "\"kind\":\"counter\",\"name\":\"ws_hit\"",
        "\"bogus\":1,\"kind\":\"counter\",\"name\":\"ws_hit\"",
    );
    assert_ne!(bad, text);
    assert!(TraceReport::parse_jsonl(&bad).is_err(), "unknown key accepted");

    // Unknown event kind.
    let bad = text.replace("\"kind\":\"counter\"", "\"kind\":\"gauge\"");
    assert!(TraceReport::parse_jsonl(&bad).is_err(), "unknown kind accepted");

    // Wrong schema tag.
    let bad = text.replacen("sagebwd-trace-v1", "sagebwd-trace-v2", 1);
    assert!(TraceReport::parse_jsonl(&bad).is_err(), "wrong schema accepted");

    // Missing meta line.
    let bad: String = text.lines().skip(1).map(|l| format!("{l}\n")).collect();
    assert!(TraceReport::parse_jsonl(&bad).is_err(), "missing meta accepted");

    // Meta counts disagreeing with the event lines.
    let bad = text.replace("\"spans\":3", "\"spans\":4");
    assert!(TraceReport::parse_jsonl(&bad).is_err(), "count drift accepted");

    // Malformed JSON line.
    let bad = format!("{text}{{\"schema\":");
    assert!(TraceReport::parse_jsonl(&bad).is_err(), "malformed accepted");
}
