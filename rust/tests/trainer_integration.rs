//! End-to-end coordinator tests: Trainer over real artifacts.

use sagebwd::config::TrainConfig;
use sagebwd::coordinator::{RunStatus, Trainer};
use sagebwd::runtime::Runtime;
use sagebwd::telemetry::Log;

fn runtime() -> Option<Runtime> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("grad_step_sage_qknorm.manifest.json").exists() {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        return None;
    }
    Some(Runtime::new(dir).expect("creating runtime"))
}

fn cfg(variant: &str, steps: u64, tps: u64) -> TrainConfig {
    TrainConfig {
        variant: variant.into(),
        steps,
        tokens_per_step: tps,
        warmup_steps: 1,
        peak_lr: 3e-3,
        min_lr_frac: 0.1,
        seed: 0,
        checkpoint_every: 0,
        log_every: 0,
        clip_norm: 0.0,
        grad_noise_sigma: 0.0,
        ..TrainConfig::default()
    }
}

#[test]
fn five_steps_reduce_loss_sage() {
    let Some(rt) = runtime() else { return };
    let mut t = Trainer::new(rt, cfg("sage_qknorm", 5, 512)).unwrap();
    let mut b = t.make_byte_batcher(2);
    let report = t.run(&mut b, &Log::new(false)).unwrap();
    assert_eq!(report.status, RunStatus::Completed);
    assert_eq!(report.steps_done, 5);
    assert_eq!(report.tokens_seen, 5 * 512);
    let losses = &t.metrics.get("train_loss").unwrap().points;
    assert!(losses.last().unwrap().1 < losses[0].1, "{losses:?}");
}

#[test]
fn fpa_variant_trains_too() {
    let Some(rt) = runtime() else { return };
    let mut t = Trainer::new(rt, cfg("fpa_qknorm", 3, 512)).unwrap();
    let mut b = t.make_byte_batcher(2);
    let report = t.run(&mut b, &Log::new(false)).unwrap();
    assert_eq!(report.status, RunStatus::Completed);
}

#[test]
fn training_is_deterministic() {
    let Some(rt1) = runtime() else { return };
    let Some(rt2) = runtime() else { return };
    let run = |rt: Runtime| {
        let mut t = Trainer::new(rt, cfg("sage_qknorm", 2, 512)).unwrap();
        let mut b = t.make_byte_batcher(2);
        t.run(&mut b, &Log::new(false)).unwrap();
        t.metrics.get("train_loss").unwrap().points.clone()
    };
    assert_eq!(run(rt1), run(rt2));
}

#[test]
fn tps_controls_microbatch_count() {
    let Some(rt) = runtime() else { return };
    let t = Trainer::new(rt, cfg("sage_qknorm", 2, 2048)).unwrap();
    let (b, n) = t.microbatch_shape();
    assert_eq!(t.microbatches_per_step(), 2048 / (b * n) as u64);
}

#[test]
fn invalid_tps_rejected() {
    let Some(rt) = runtime() else { return };
    // 500 is not a multiple of microbatch×seq_len (2×128).
    assert!(Trainer::new(rt, cfg("sage_qknorm", 2, 500)).is_err());
}

#[test]
fn checkpoint_roundtrip_resumes_identically() {
    let Some(rt1) = runtime() else { return };
    let Some(rt2) = runtime() else { return };
    let path = std::env::temp_dir().join(format!("sagebwd_it_{}.ckpt", std::process::id()));

    // Train 2 steps, checkpoint, train 1 more.
    let mut a = Trainer::new(rt1, cfg("sage_qknorm", 3, 512)).unwrap();
    let mut ba = a.make_byte_batcher(2);
    a.train_step(&mut ba).unwrap();
    a.train_step(&mut ba).unwrap();
    a.save_checkpoint(&path).unwrap();
    let loss_a = a.train_step(&mut ba).unwrap();

    // Restore into a fresh trainer; replay the same third batch.
    let mut b = Trainer::new(rt2, cfg("sage_qknorm", 3, 512)).unwrap();
    let mut bb = b.make_byte_batcher(2);
    // Advance the data stream to where `a` was at the checkpoint.
    for _ in 0..2 {
        b.train_step(&mut bb).unwrap();
    }
    b.load_checkpoint(&path).unwrap();
    let loss_b = b.train_step(&mut bb).unwrap();
    assert!(
        (loss_a - loss_b).abs() < 1e-6,
        "resume mismatch: {loss_a} vs {loss_b}"
    );
    std::fs::remove_file(&path).ok();
}

#[test]
fn metrics_flush_produces_csv() {
    let Some(rt) = runtime() else { return };
    let mut t = Trainer::new(rt, cfg("sage_qknorm", 2, 512)).unwrap();
    let mut b = t.make_byte_batcher(2);
    t.run(&mut b, &Log::new(false)).unwrap();
    let dir = std::env::temp_dir().join(format!("sagebwd_metrics_{}", std::process::id()));
    t.metrics.flush_csv(&dir).unwrap();
    let loss_csv = std::fs::read_to_string(dir.join("train_loss.csv")).unwrap();
    assert!(loss_csv.lines().count() >= 3); // header + 2 steps
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn noise_injection_changes_trajectory_but_stays_finite() {
    let Some(rt1) = runtime() else { return };
    let Some(rt2) = runtime() else { return };
    let run = |rt: Runtime, sigma: f64| {
        let mut c = cfg("sage_qknorm", 3, 512);
        c.grad_noise_sigma = sigma;
        let mut t = Trainer::new(rt, c).unwrap();
        let mut b = t.make_byte_batcher(2);
        let report = t.run(&mut b, &Log::new(false)).unwrap();
        assert_eq!(report.status, RunStatus::Completed);
        report.final_loss.unwrap()
    };
    let clean = run(rt1, 0.0);
    let noisy = run(rt2, 0.5);
    assert!(clean.is_finite() && noisy.is_finite());
    assert!((clean - noisy).abs() > 1e-9, "noise had no effect");
}

#[test]
fn clipping_bounds_grad_norm_metric() {
    let Some(rt) = runtime() else { return };
    let mut c = cfg("sage_qknorm", 2, 512);
    c.clip_norm = 1e-3; // aggressive clip: recorded pre-clip norm unaffected,
                        // but training must remain stable and finite
    let mut t = Trainer::new(rt, c).unwrap();
    let mut b = t.make_byte_batcher(2);
    let report = t.run(&mut b, &Log::new(false)).unwrap();
    assert_eq!(report.status, RunStatus::Completed);
    assert!(report.final_loss.unwrap().is_finite());
}
