//! Vendored minimal `anyhow`-compatible error substrate.
//!
//! The offline build environment has no crates.io access, so this crate
//! reimplements the subset of the `anyhow` 1.x API the repo uses:
//!
//! * [`Error`] — a context chain of display strings (outermost first).
//! * [`Result<T>`] — alias with the usual default error type.
//! * [`anyhow!`], [`bail!`], [`ensure!`] — format-style constructors.
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` (for
//!   both std error types and [`Error`] itself) and on `Option`.
//!
//! Formatting matches anyhow's conventions: `{}` prints the outermost
//! message, `{:#}` prints the whole chain joined by `": "`, and `{:?}`
//! prints the message plus a `Caused by:` list.

use std::fmt;

/// Error type: an ordered chain of messages, outermost context first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap with an outer context message (anyhow's `Error::context`).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The messages from outermost context to root cause.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

// Like real anyhow: `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket `From` coherent.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// `Result` alias with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Conversion-into-`Error` helper unifying std errors and [`Error`]
/// (anyhow's private `ext::StdError` pattern).
pub trait IntoError {
    fn into_error(self) -> Error;
}

impl IntoError for Error {
    fn into_error(self) -> Error {
        self
    }
}

impl<E> IntoError for E
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn into_error(self) -> Error {
        Error::from(self)
    }
}

/// Context-attachment on fallible values.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: IntoError> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or any displayable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($tt:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($tt)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($tt:tt)*) => {
        if !$cond {
            return ::std::result::Result::Err($crate::anyhow!($($tt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn display_and_alternate() {
        let e: Error = Error::from(io_err()).context("reading config");
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: file missing");
    }

    #[test]
    fn debug_lists_causes() {
        let e = Error::msg("root").context("mid").context("outer");
        let s = format!("{e:?}");
        assert!(s.starts_with("outer"));
        assert!(s.contains("Caused by:"));
        assert!(s.contains("root"));
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(f().unwrap_err().root_cause(), "file missing");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: file missing");

        let o: Option<u32> = None;
        assert_eq!(format!("{}", o.context("missing").unwrap_err()), "missing");
        assert_eq!(Some(3u32).with_context(|| "x").unwrap(), 3);
    }

    #[test]
    fn context_on_anyhow_result() {
        let r: Result<()> = Err(anyhow!("inner {}", 7));
        let e = r.with_context(|| format!("step {}", 1)).unwrap_err();
        assert_eq!(format!("{e:#}"), "step 1: inner 7");
    }

    #[test]
    fn macros() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative: {x}");
            if x == 0 {
                bail!("zero");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert_eq!(format!("{}", f(0).unwrap_err()), "zero");
        assert_eq!(format!("{}", f(-1).unwrap_err()), "negative: -1");
        let owned = anyhow!(String::from("owned message"));
        assert_eq!(format!("{owned}"), "owned message");
    }
}
