//! Vendored stand-in for the `xla-rs` bindings used by the coordinator's
//! XLA artifact path.
//!
//! Two halves with very different fidelity:
//!
//! * **Host-side [`Literal`]** — fully functional. Shape + element type +
//!   raw little-endian bytes, exactly the interchange
//!   `runtime::literal` relies on (`create_from_shape_and_untyped_data`,
//!   `to_vec`, `array_shape`, `ty`). Unit and property tests over the
//!   literal conversion layer run everywhere.
//!
//! * **PJRT device path** ([`PjRtClient`], [`PjRtLoadedExecutable`],
//!   [`PjRtBuffer`], [`HloModuleProto`]) — API-compatible stubs whose
//!   constructors return a descriptive [`Error`]. The real PJRT runtime is
//!   not linked into offline builds; `Runtime::new` therefore fails fast
//!   with a clear message and every harness that can run on the native CPU
//!   kernel backend (`sagebwd --backend native`, see DESIGN.md §4) does so
//!   without touching this path.

use std::fmt;

/// Stub error carrying a description (rendered via `{:?}` by callers).
#[derive(Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: the XLA PJRT runtime is not linked into this build — \
         use the native kernel backend (--backend native) or a build with \
         the real xla-rs bindings"
    ))
}

/// XLA element types (subset the artifacts use, plus a few for realism).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    F64,
    S64,
    U32,
    Pred,
}

impl ElementType {
    pub fn size_bytes(self) -> usize {
        match self {
            ElementType::F32 | ElementType::S32 | ElementType::U32 => 4,
            ElementType::F64 | ElementType::S64 => 8,
            ElementType::Pred => 1,
        }
    }
}

/// Host types that can live inside a [`Literal`].
pub trait NativeType: Copy + 'static {
    const TY: ElementType;
    fn write_le(data: &[Self], out: &mut Vec<u8>);
    fn read_le(bytes: &[u8]) -> Vec<Self>;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;

    fn write_le(data: &[Self], out: &mut Vec<u8>) {
        for x in data {
            out.extend_from_slice(&x.to_le_bytes());
        }
    }

    fn read_le(bytes: &[u8]) -> Vec<Self> {
        bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect()
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;

    fn write_le(data: &[Self], out: &mut Vec<u8>) {
        for x in data {
            out.extend_from_slice(&x.to_le_bytes());
        }
    }

    fn read_le(bytes: &[u8]) -> Vec<Self> {
        bytes
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect()
    }
}

/// Array shape of a non-tuple literal.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn element_type(&self) -> ElementType {
        self.ty
    }
}

/// Host literal: either a dense array (type + dims + LE bytes) or a tuple.
#[derive(Debug, Clone)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<i64>,
    bytes: Vec<u8>,
    tuple: Option<Vec<Literal>>,
}

impl Literal {
    /// Build a dense literal from raw little-endian bytes.
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let numel: usize = dims.iter().product();
        let want = numel * ty.size_bytes();
        if data.len() != want {
            return Err(Error(format!(
                "literal {ty:?}{dims:?} wants {want} bytes, got {}",
                data.len()
            )));
        }
        Ok(Literal {
            ty,
            dims: dims.iter().map(|&d| d as i64).collect(),
            bytes: data.to_vec(),
            tuple: None,
        })
    }

    /// Build a tuple literal (what `return_tuple=True` executables yield).
    pub fn tuple(parts: Vec<Literal>) -> Literal {
        Literal {
            ty: ElementType::Pred,
            dims: Vec::new(),
            bytes: Vec::new(),
            tuple: Some(parts),
        }
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        if self.tuple.is_some() {
            return Err(Error("array_shape on a tuple literal".into()));
        }
        Ok(ArrayShape {
            dims: self.dims.clone(),
            ty: self.ty,
        })
    }

    pub fn ty(&self) -> Result<ElementType> {
        if self.tuple.is_some() {
            return Err(Error("ty on a tuple literal".into()));
        }
        Ok(self.ty)
    }

    /// Decode to a typed host vector; errors on element-type mismatch.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.tuple.is_some() {
            return Err(Error("to_vec on a tuple literal".into()));
        }
        if self.ty != T::TY {
            return Err(Error(format!(
                "literal is {:?}, requested {:?}",
                self.ty,
                T::TY
            )));
        }
        Ok(T::read_le(&self.bytes))
    }

    /// Split a tuple literal into its parts.
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        self.tuple
            .take()
            .ok_or_else(|| Error("decompose_tuple on a non-tuple literal".into()))
    }
}

/// PJRT client stub — construction reports the runtime as unavailable.
#[derive(Debug, Clone)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("creating PJRT CPU client"))
    }

    pub fn platform_name(&self) -> String {
        "unavailable".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("compiling HLO"))
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(unavailable("uploading host buffer"))
    }
}

/// Parsed HLO module stub.
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("parsing HLO text"))
    }
}

/// XLA computation stub.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Loaded executable stub.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b<T>(&self, _inputs: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("executing"))
    }
}

/// Device buffer stub.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("downloading buffer"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let data = [1.5f32, -2.0, 0.25];
        let mut bytes = Vec::new();
        f32::write_le(&data, &mut bytes);
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &bytes).unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), data);
        assert_eq!(lit.array_shape().unwrap().dims(), &[3]);
        assert_eq!(lit.ty().unwrap(), ElementType::F32);
    }

    #[test]
    fn literal_type_mismatch_rejected() {
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[1], &[0, 0, 0, 0])
                .unwrap();
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn literal_size_checked() {
        assert!(
            Literal::create_from_shape_and_untyped_data(ElementType::S32, &[2], &[0u8; 7]).is_err()
        );
    }

    #[test]
    fn tuple_decomposes_once() {
        let part =
            Literal::create_from_shape_and_untyped_data(ElementType::S32, &[1], &[1, 0, 0, 0])
                .unwrap();
        let mut tup = Literal::tuple(vec![part]);
        assert!(tup.array_shape().is_err());
        let parts = tup.decompose_tuple().unwrap();
        assert_eq!(parts.len(), 1);
        assert!(tup.decompose_tuple().is_err());
    }

    #[test]
    fn pjrt_paths_fail_fast_with_guidance() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(format!("{err:?}").contains("--backend native"));
    }
}
